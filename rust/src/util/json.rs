//! Minimal JSON writer *and* parser for metrics / experiment output and
//! the golden-parity fixtures (`rust/tests/fixtures/`). Hand-rolled
//! because no serde is vendored in the offline image.

use std::fmt::Write as _;

/// A JSON value that can render itself to a string.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Parse a JSON document (strict enough for our own writer's output
    /// and the python-generated fixtures: objects, arrays, strings with
    /// standard escapes incl. `\uXXXX`, f64 numbers, bools, null).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let value = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(value)
    }

    /// Object field lookup (first match; `None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn eat_keyword(&mut self, word: &str) -> bool {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') | Some(b'f') => {
                if self.eat_keyword("true") {
                    Ok(Json::Bool(true))
                } else if self.eat_keyword("false") {
                    Ok(Json::Bool(false))
                } else {
                    anyhow::bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(b'n') => {
                if self.eat_keyword("null") {
                    Ok(Json::Null)
                } else {
                    anyhow::bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                anyhow::bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        anyhow::bail!("dangling escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok());
                            let Some(code) = hex else {
                                anyhow::bail!("bad \\u escape at byte {}", self.pos);
                            };
                            self.pos += 4;
                            // surrogate pairs are not needed by our fixtures
                            let Some(c) = char::from_u32(code) else {
                                anyhow::bail!("non-scalar \\u escape at byte {}", self.pos);
                            };
                            out.push(c);
                        }
                        other => anyhow::bail!("unknown escape {:?} at byte {}", other as char, self.pos),
                    }
                }
                _ => {
                    // consume the full UTF-8 sequence starting at b
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0b1100_0000 == 0b1000_0000 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 at byte {}", start))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let raw = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {:?} at byte {}", raw, start))?;
        Ok(Json::Num(n))
    }
}

impl Json {
    /// Render with no whitespace.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // Integral values print without a fraction.
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Run id under which this build's bench sections are recorded in
/// `BENCH_engine.json` — the committed perf record is **append-only
/// keyed by PR/run id** (DESIGN.md §Perf): each bench target's section
/// is an object mapping run ids to that run's measurements, and
/// [`merge_report`]'s deep-merge only ever touches the current id's
/// slot, so prior PRs' entries survive every re-run. Override with the
/// `BENCH_RUN_ID` env var at *compile* time (the driver sets it per
/// PR); defaults to the id of the PR that introduced the record.
pub const BENCH_RUN_ID: &str = match option_env!("BENCH_RUN_ID") {
    Some(id) => id,
    None => "pr10",
};

/// Wrap a bench section's measurements under the current
/// [`BENCH_RUN_ID`], producing the `{run_id: {...measurements}}` shape
/// [`merge_report`] appends without clobbering other runs' entries.
pub fn keyed_by_run(value: Json) -> Json {
    Json::Obj(vec![(BENCH_RUN_ID.to_string(), value)])
}

/// Merge `entries` into the JSON object stored at `path`. The merge is
/// **deep on objects**: when an existing key and its replacement are
/// both objects, their fields merge recursively (new sub-keys append,
/// shared sub-keys recurse), so run-id-keyed bench sections
/// ([`keyed_by_run`]) are append-only — re-running a bench target
/// updates only the current run's slot and every other run's entry
/// survives. Non-object values (and object/non-object mismatches)
/// replace, which is what re-measured leaf numbers want. Creates the
/// file if missing; an unreadable/non-object file is replaced
/// wholesale. Shared by the bench harness and the `bench-client` CLI
/// subcommand, both of which track measurements in `BENCH_engine.json`
/// at the repository root.
pub fn merge_report(path: &std::path::Path, entries: Vec<(String, Json)>) -> std::io::Result<()> {
    let mut fields: Vec<(String, Json)> = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(fields)) => fields,
            _ => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    merge_fields(&mut fields, entries);
    std::fs::write(path, Json::Obj(fields).render())
}

/// Recursive object merge behind [`merge_report`].
fn merge_fields(fields: &mut Vec<(String, Json)>, entries: Vec<(String, Json)>) {
    for (key, value) in entries {
        if let Some(slot) = fields.iter_mut().find(|(k, _)| *k == key) {
            match (&mut slot.1, value) {
                (Json::Obj(existing), Json::Obj(incoming)) => merge_fields(existing, incoming),
                (slot_value, other) => *slot_value = other,
            }
        } else {
            fields.push((key, value));
        }
    }
}

/// Convenience builder for JSON objects.
#[derive(Debug, Default)]
pub struct ObjBuilder {
    fields: Vec<(String, Json)>,
}

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn field(mut self, key: &str, value: Json) -> Self {
        self.fields.push((key.to_string(), value));
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::num(3).render(), "3");
        assert_eq!(Json::num(3.5).render(), "3.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::str("\u{01}").render(), "\"\\u0001\"");
    }

    #[test]
    fn renders_nested() {
        let j = ObjBuilder::new()
            .field("xs", Json::Arr(vec![Json::num(1), Json::num(2)]))
            .field("name", Json::str("mcam"))
            .build();
        assert_eq!(j.render(), r#"{"xs":[1,2],"name":"mcam"}"#);
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = ObjBuilder::new()
            .field("xs", Json::Arr(vec![Json::num(1), Json::num(-2.5), Json::Null]))
            .field("name", Json::str("mcam \"quoted\"\n"))
            .field("ok", Json::Bool(true))
            .build();
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn parse_handles_whitespace_and_nesting() {
        let parsed = Json::parse(
            " {\n  \"a\": [ 1 , 2.5e2 , {\"b\": false} ],\n  \"c\": null }\n",
        )
        .unwrap();
        let a = parsed.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(250.0));
        assert_eq!(a[2].get("b").unwrap().as_bool(), Some(false));
        assert_eq!(parsed.get("c"), Some(&Json::Null));
        assert_eq!(parsed.get("missing"), None);
    }

    #[test]
    fn parse_unicode_escapes() {
        let parsed = Json::parse(r#"{"s": "café ✓"}"#).unwrap();
        assert_eq!(parsed.get("s").unwrap().as_str(), Some("café ✓"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn merge_report_preserves_and_replaces_keys() {
        let dir = std::env::temp_dir().join("mcamvss_json_merge");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        let _ = std::fs::remove_file(&path);

        // creates the file
        merge_report(&path, vec![("a".into(), Json::num(1)), ("b".into(), Json::num(2))])
            .unwrap();
        // replaces re-measured keys, keeps the rest
        merge_report(&path, vec![("b".into(), Json::num(9))]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(parsed.get("b").unwrap().as_f64(), Some(9.0));

        // a corrupt file is replaced wholesale, not a crash
        std::fs::write(&path, "not json").unwrap();
        merge_report(&path, vec![("c".into(), Json::num(3))]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("c").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("a"), None);
    }

    #[test]
    fn merge_report_is_append_only_for_run_keyed_sections() {
        // The committed-perf-record contract (DESIGN.md §Perf): a bench
        // section is an object keyed by run id, and merging a second
        // run's entry must preserve the first — two merges, both entries
        // survive. A re-merge of the SAME run id updates only that slot.
        let dir = std::env::temp_dir().join("mcamvss_json_merge_runs");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.json");
        let _ = std::fs::remove_file(&path);

        let entry = |ms: f64| ObjBuilder::new().field("kernel_ms", Json::num(ms)).build();
        merge_report(
            &path,
            vec![("perf_kernel".into(), Json::Obj(vec![("pr9".into(), entry(4.0))]))],
        )
        .unwrap();
        merge_report(
            &path,
            vec![("perf_kernel".into(), Json::Obj(vec![("pr10".into(), entry(2.0))]))],
        )
        .unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let section = parsed.get("perf_kernel").unwrap();
        assert_eq!(section.get("pr9").unwrap().get("kernel_ms").unwrap().as_f64(), Some(4.0));
        assert_eq!(section.get("pr10").unwrap().get("kernel_ms").unwrap().as_f64(), Some(2.0));

        // re-running the current id replaces only its own slot
        merge_report(
            &path,
            vec![("perf_kernel".into(), Json::Obj(vec![("pr10".into(), entry(1.5))]))],
        )
        .unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let section = parsed.get("perf_kernel").unwrap();
        assert_eq!(section.get("pr9").unwrap().get("kernel_ms").unwrap().as_f64(), Some(4.0));
        assert_eq!(section.get("pr10").unwrap().get("kernel_ms").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn keyed_by_run_wraps_under_current_run_id() {
        let wrapped = keyed_by_run(Json::num(7));
        assert_eq!(wrapped.get(BENCH_RUN_ID).unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::num(8).as_usize(), Some(8));
        assert_eq!(Json::num(8.5).as_usize(), None);
        assert_eq!(Json::num(-1).as_usize(), None);
    }
}
