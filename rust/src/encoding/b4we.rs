//! Base-4 *weighted* encoding (B4WE) [19]: B4E digits with digit *i*
//! physically duplicated `4^i` times, so unweighted vote accumulation
//! realises the base-4 digit weighting while the duplication adds SRE-like
//! robustness. Word length `(4^cl - 1) / 3` — 1, 5, 21 for base lengths
//! 1, 2, 3 (the Fig. 9 data points).

use super::b4e::encode_b4e;

/// Physical word count for `base_cl` base-4 digits.
pub fn b4we_word_length(base_cl: usize) -> usize {
    assert!(base_cl >= 1);
    (4usize.pow(base_cl as u32) - 1) / 3
}

/// Append the B4WE code words for `value` (digit *i* repeated `4^i`
/// times, LSB first).
pub fn encode_b4we(value: u32, base_cl: usize, out: &mut Vec<u8>) {
    let mut digits = Vec::with_capacity(base_cl);
    encode_b4e(value, base_cl, &mut digits);
    for (i, &d) in digits.iter().enumerate() {
        for _ in 0..4usize.pow(i as u32) {
            out.push(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_lengths_match_fig9() {
        assert_eq!(b4we_word_length(1), 1);
        assert_eq!(b4we_word_length(2), 5);
        assert_eq!(b4we_word_length(3), 21);
    }

    #[test]
    fn duplication_counts() {
        // 7 = digits (3, 1): digit0 x1, digit1 x4.
        let mut out = Vec::new();
        encode_b4we(7, 2, &mut out);
        assert_eq!(out, vec![3, 1, 1, 1, 1]);
    }

    #[test]
    fn length_matches_formula() {
        for base_cl in 1..=3 {
            let mut out = Vec::new();
            encode_b4we(1, base_cl, &mut out);
            assert_eq!(out.len(), b4we_word_length(base_cl));
        }
    }
}
