//! Multi-bit thermometer code (MTMC) — the paper's contribution (§3.1,
//! Table 1). Value `m` with code word length `cl` becomes `cl - n` words
//! of `x` followed by `n` words of `x + 1`, where `x = m / cl`,
//! `n = m % cl`. Two properties drive the paper's results:
//!
//! * **L1 preservation**: `Σ_i |enc(a)_i − enc(b)_i| == |a − b|`, and
//! * **bounded bottleneck**: `|a − b| < cl` implies every word mismatch
//!   is ≤ 1 — no single mismatch-3 cell can throttle the string current
//!   for nearby value pairs.

/// Append the `cl` MTMC code words for `value` (must be `<= 3*cl`).
pub fn encode_mtmc(value: u32, cl: usize, out: &mut Vec<u8>) {
    assert!(
        (value as usize) <= 3 * cl,
        "MTMC value {value} out of range for cl={cl}"
    );
    let x = (value as usize / cl) as u8;
    let n = value as usize % cl;
    for j in 0..cl {
        out.push(if j >= cl - n { x + 1 } else { x });
    }
}

/// Inverse of [`encode_mtmc`]: the word sum equals the value.
pub fn decode_mtmc(words: &[u8]) -> u32 {
    words.iter().map(|&w| w as u32).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn table1_rows() {
        // Paper Table 1, CL=5: every value 0..=15.
        let expected: [&[u8; 5]; 16] = [
            &[0, 0, 0, 0, 0],
            &[0, 0, 0, 0, 1],
            &[0, 0, 0, 1, 1],
            &[0, 0, 1, 1, 1],
            &[0, 1, 1, 1, 1],
            &[1, 1, 1, 1, 1],
            &[1, 1, 1, 1, 2],
            &[1, 1, 1, 2, 2],
            &[1, 1, 2, 2, 2],
            &[1, 2, 2, 2, 2],
            &[2, 2, 2, 2, 2],
            &[2, 2, 2, 2, 3],
            &[2, 2, 2, 3, 3],
            &[2, 2, 3, 3, 3],
            &[2, 3, 3, 3, 3],
            &[3, 3, 3, 3, 3],
        ];
        for (value, want) in expected.iter().enumerate() {
            let mut out = Vec::new();
            encode_mtmc(value as u32, 5, &mut out);
            assert_eq!(&out[..], &want[..], "value {value}");
        }
    }

    #[test]
    fn l1_preserved() {
        forall(
            "mtmc L1 preservation",
            256,
            |rng| {
                let cl = 1 + rng.below(32);
                let a = rng.below(3 * cl + 1) as u32;
                let b = rng.below(3 * cl + 1) as u32;
                (cl, a, b)
            },
            |&(cl, a, b)| {
                let (mut wa, mut wb) = (Vec::new(), Vec::new());
                encode_mtmc(a, cl, &mut wa);
                encode_mtmc(b, cl, &mut wb);
                let l1: u32 = wa
                    .iter()
                    .zip(&wb)
                    .map(|(&x, &y)| (x as i32 - y as i32).unsigned_abs())
                    .sum();
                l1 == a.abs_diff(b)
            },
        );
    }

    #[test]
    fn max_mismatch_bounded_for_near_values() {
        forall(
            "mtmc bounded bottleneck",
            256,
            |rng| {
                let cl = 2 + rng.below(30);
                let a = rng.below(3 * cl + 1) as i64;
                let delta = rng.below(2 * cl - 1) as i64 - (cl as i64 - 1);
                let b = (a + delta).clamp(0, 3 * cl as i64);
                (cl, a as u32, b as u32)
            },
            |&(cl, a, b)| {
                if a.abs_diff(b) as usize >= cl {
                    return true; // property only claims |a-b| < cl
                }
                let (mut wa, mut wb) = (Vec::new(), Vec::new());
                encode_mtmc(a, cl, &mut wa);
                encode_mtmc(b, cl, &mut wb);
                wa.iter()
                    .zip(&wb)
                    .map(|(&x, &y)| (x as i32 - y as i32).unsigned_abs())
                    .max()
                    .unwrap()
                    <= 1
            },
        );
    }

    #[test]
    fn roundtrip() {
        for cl in [1usize, 3, 5, 25, 32] {
            for value in 0..=(3 * cl) as u32 {
                let mut out = Vec::new();
                encode_mtmc(value, cl, &mut out);
                assert_eq!(decode_mtmc(&out), value);
            }
        }
    }

    #[test]
    fn thermometer_monotone_superset() {
        // Cumulative/thermometer invariant: the code for level k dominates
        // the code for level k−1 elementwise (enc(k)_j >= enc(k−1)_j for
        // every word j) and raises exactly one word by one level — the
        // "superset" structure that makes word sums decode the value.
        forall(
            "mtmc level k is a superset of level k-1",
            256,
            |rng| {
                let cl = 1 + rng.below(32);
                let k = 1 + rng.below(3 * cl) as u32;
                (cl, k)
            },
            |&(cl, k)| {
                let (mut prev, mut curr) = (Vec::new(), Vec::new());
                encode_mtmc(k - 1, cl, &mut prev);
                encode_mtmc(k, cl, &mut curr);
                let dominated = prev.iter().zip(&curr).all(|(&a, &b)| b >= a);
                // signed arithmetic: on a regression (b < a) this must
                // report the counterexample, not overflow-panic
                let raised: i32 = curr
                    .iter()
                    .zip(&prev)
                    .map(|(&b, &a)| b as i32 - a as i32)
                    .sum();
                dominated && raised == 1
            },
        );
    }

    #[test]
    fn words_are_monotone_in_value() {
        // Every word position is non-decreasing as the value grows (the
        // panel-wide consequence of the superset property).
        for cl in [2usize, 5, 8, 32] {
            let mut prev: Option<Vec<u8>> = None;
            for value in 0..=(3 * cl) as u32 {
                let mut curr = Vec::new();
                encode_mtmc(value, cl, &mut curr);
                if let Some(prev) = prev {
                    assert!(
                        prev.iter().zip(&curr).all(|(&a, &b)| b >= a),
                        "cl={cl} value={value}"
                    );
                }
                prev = Some(curr);
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_overflow() {
        encode_mtmc(16, 5, &mut Vec::new());
    }
}
