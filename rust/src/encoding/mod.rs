//! Code-word encodings for the MCAM (mirror of `python/compile/encodings.py`).
//!
//! Every encoder maps integer-quantized values in `[0, levels)` to 4-ary
//! code words in `{0,1,2,3}`, one per MLC unit cell. The four schemes the
//! paper evaluates:
//!
//! * [`Encoding::Sre`]  — simple repetition encoding [11],
//! * [`Encoding::B4e`]  — base-4 bit slicing [18] (digit *i* weighted
//!   `4^i` in the Eq.-2 accumulation),
//! * [`Encoding::B4we`] — base-4 weighted encoding [19] (digit *i*
//!   duplicated `4^i` times),
//! * [`Encoding::Mtmc`] — the paper's multi-bit thermometer code, which
//!   preserves L1 distance exactly and bounds the per-word mismatch for
//!   nearby values (§3.1).
//!
//! Python/rust equivalence is proven by the shared test vectors under
//! `artifacts/testvec/` (see `rust/tests/test_crosslayer.rs`).

mod b4e;
mod b4we;
mod mtmc;
mod sre;

pub mod analysis;

pub use b4e::{decode_b4e, encode_b4e};
pub use b4we::{b4we_word_length, encode_b4we};
pub use mtmc::{decode_mtmc, encode_mtmc};
pub use sre::encode_sre;

/// The four code-word encoding schemes of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Encoding {
    Sre,
    B4e,
    B4we,
    Mtmc,
}

pub const ALL_ENCODINGS: [Encoding; 4] =
    [Encoding::Sre, Encoding::B4e, Encoding::B4we, Encoding::Mtmc];

impl Encoding {
    pub fn name(&self) -> &'static str {
        match self {
            Encoding::Sre => "sre",
            Encoding::B4e => "b4e",
            Encoding::B4we => "b4we",
            Encoding::Mtmc => "mtmc",
        }
    }

    pub fn from_name(name: &str) -> Option<Encoding> {
        match name {
            "sre" => Some(Encoding::Sre),
            "b4e" => Some(Encoding::B4e),
            "b4we" => Some(Encoding::B4we),
            "mtmc" => Some(Encoding::Mtmc),
            _ => None,
        }
    }

    /// Quantization levels afforded at code word length `cl` (for B4WE,
    /// `cl` is the *base* digit count; the physical length is larger).
    pub fn levels(&self, cl: usize) -> usize {
        assert!(cl >= 1, "code word length must be >= 1");
        match self {
            Encoding::Sre => 4,
            Encoding::B4e | Encoding::B4we => {
                4usize.checked_pow(cl as u32).expect("levels overflow")
            }
            Encoding::Mtmc => 3 * cl + 1,
        }
    }

    /// Physical code words stored per dimension.
    pub fn word_length(&self, cl: usize) -> usize {
        assert!(cl >= 1, "code word length must be >= 1");
        match self {
            Encoding::Sre | Encoding::B4e | Encoding::Mtmc => cl,
            Encoding::B4we => b4we_word_length(cl),
        }
    }

    /// Encode one value into its code words (appended to `out`).
    pub fn encode_into(&self, value: u32, cl: usize, out: &mut Vec<u8>) {
        debug_assert!(
            (value as usize) < self.levels(cl),
            "value {value} out of range for {self:?} cl={cl}"
        );
        match self {
            Encoding::Sre => encode_sre(value, cl, out),
            Encoding::B4e => encode_b4e(value, cl, out),
            Encoding::B4we => encode_b4we(value, cl, out),
            Encoding::Mtmc => encode_mtmc(value, cl, out),
        }
    }

    /// Encode one value, returning a fresh vec (convenience for tests).
    pub fn encode(&self, value: u32, cl: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.word_length(cl));
        self.encode_into(value, cl, &mut out);
        out
    }

    /// Encode a whole vector: `values.len() * word_length(cl)` words,
    /// dimension-major.
    pub fn encode_vector(&self, values: &[u32], cl: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(values.len() * self.word_length(cl));
        for &v in values {
            self.encode_into(v, cl, &mut out);
        }
        out
    }

    /// Per-code-word accumulation weights `s_i` (paper Eq. 2): B4E weights
    /// digit *i* by `4^i`, all other schemes are uniform (B4WE realises
    /// the weighting through duplication).
    pub fn accumulation_weights(&self, cl: usize) -> Vec<f64> {
        match self {
            Encoding::B4e => (0..cl).map(|i| 4f64.powi(i as i32)).collect(),
            _ => vec![1.0; self.word_length(cl)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn names_roundtrip() {
        for enc in ALL_ENCODINGS {
            assert_eq!(Encoding::from_name(enc.name()), Some(enc));
        }
        assert_eq!(Encoding::from_name("nope"), None);
    }

    #[test]
    fn levels_match_paper() {
        assert_eq!(Encoding::Sre.levels(7), 4);
        assert_eq!(Encoding::B4e.levels(3), 64);
        assert_eq!(Encoding::Mtmc.levels(5), 16);
        assert_eq!(Encoding::Mtmc.levels(32), 97);
        assert_eq!(Encoding::B4we.levels(3), 64);
    }

    #[test]
    fn word_lengths() {
        assert_eq!(Encoding::Sre.word_length(6), 6);
        assert_eq!(Encoding::B4e.word_length(6), 6);
        assert_eq!(Encoding::Mtmc.word_length(25), 25);
        // Fig. 9 B4WE data points: 1, 5, 21
        assert_eq!(Encoding::B4we.word_length(1), 1);
        assert_eq!(Encoding::B4we.word_length(2), 5);
        assert_eq!(Encoding::B4we.word_length(3), 21);
    }

    #[test]
    fn all_words_are_2bit() {
        forall(
            "words in 0..=3",
            64,
            |rng| {
                let enc = ALL_ENCODINGS[rng.below(4)];
                let cl = 1 + rng.below(5);
                let value = rng.below(enc.levels(cl)) as u32;
                (enc, cl, value)
            },
            |&(enc, cl, value)| {
                let words = enc.encode(value, cl);
                words.len() == enc.word_length(cl) && words.iter().all(|&w| w <= 3)
            },
        );
    }

    #[test]
    fn vector_encoding_is_dimension_major() {
        let words = Encoding::Mtmc.encode_vector(&[0, 5, 15], 5);
        assert_eq!(words.len(), 15);
        assert_eq!(&words[0..5], &[0, 0, 0, 0, 0]);
        assert_eq!(&words[5..10], &[1, 1, 1, 1, 1]);
        assert_eq!(&words[10..15], &[3, 3, 3, 3, 3]);
    }

    #[test]
    fn weights() {
        assert_eq!(Encoding::B4e.accumulation_weights(3), vec![1.0, 4.0, 16.0]);
        assert_eq!(Encoding::Mtmc.accumulation_weights(3), vec![1.0; 3]);
        assert_eq!(Encoding::B4we.accumulation_weights(2).len(), 5);
    }
}
