//! Mismatch-level analytics behind Figs. 3 and 5 of the paper.
//!
//! Two analyses:
//!
//! * [`mismatch_type_distribution`] — fraction of code-word positions at
//!   each mismatch level (0..=3) over a set of value pairs (Figs. 3(a),
//!   5(a): target vs non-target query/support pairs at various CL).
//! * [`max_mismatch_by_distance`] — for every value pair `(a, b)` of a
//!   quantization grid, the probability that the *maximum* word mismatch
//!   equals each level, bucketed by `|a - b|` (Figs. 3(b), 5(b)).

use super::Encoding;

/// Counts of code-word positions at mismatch level 0, 1, 2, 3.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MismatchHistogram {
    pub counts: [u64; 4],
}

impl MismatchHistogram {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fractions at each level (0 when empty).
    pub fn fractions(&self) -> [f64; 4] {
        let total = self.total().max(1) as f64;
        [
            self.counts[0] as f64 / total,
            self.counts[1] as f64 / total,
            self.counts[2] as f64 / total,
            self.counts[3] as f64 / total,
        ]
    }

    pub fn accumulate_pair(&mut self, enc: Encoding, cl: usize, a: u32, b: u32) {
        let wa = enc.encode(a, cl);
        let wb = enc.encode(b, cl);
        for (&x, &y) in wa.iter().zip(&wb) {
            self.counts[(x as i32 - y as i32).unsigned_abs() as usize] += 1;
        }
    }
}

/// Per-code-word mismatch-type distribution over a list of value pairs.
pub fn mismatch_type_distribution(
    enc: Encoding,
    cl: usize,
    pairs: &[(u32, u32)],
) -> MismatchHistogram {
    let mut hist = MismatchHistogram::default();
    for &(a, b) in pairs {
        hist.accumulate_pair(enc, cl, a, b);
    }
    hist
}

/// One row of the Fig. 3(b)/5(b) table: at value distance `distance`, the
/// probability that the maximum word mismatch of a pair equals 0..=3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MaxMismatchRow {
    pub distance: u32,
    pub prob: [f64; 4],
    pub pairs: u64,
}

/// Sweep **all** value pairs `(a, b)` in `[0, levels)` for `enc` at `cl`,
/// bucketing the max word mismatch by `|a - b|`.
pub fn max_mismatch_by_distance(enc: Encoding, cl: usize) -> Vec<MaxMismatchRow> {
    let levels = enc.levels(cl) as u32;
    let max_distance = (levels - 1) as usize;
    let mut counts = vec![[0u64; 4]; max_distance + 1];
    let mut totals = vec![0u64; max_distance + 1];

    // Cache every encoding once; the pair sweep is O(levels^2 * words).
    let encoded: Vec<Vec<u8>> = (0..levels).map(|v| enc.encode(v, cl)).collect();
    for a in 0..levels as usize {
        for b in 0..levels as usize {
            let mx = encoded[a]
                .iter()
                .zip(&encoded[b])
                .map(|(&x, &y)| (x as i32 - y as i32).unsigned_abs())
                .max()
                .unwrap_or(0) as usize;
            let d = a.abs_diff(b);
            counts[d][mx] += 1;
            totals[d] += 1;
        }
    }

    (0..=max_distance)
        .map(|d| {
            let total = totals[d].max(1) as f64;
            MaxMismatchRow {
                distance: d as u32,
                prob: [
                    counts[d][0] as f64 / total,
                    counts[d][1] as f64 / total,
                    counts[d][2] as f64 / total,
                    counts[d][3] as f64 / total,
                ],
                pairs: totals[d],
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_fractions_sum_to_one() {
        let pairs: Vec<(u32, u32)> = (0..16).map(|a| (a, (a + 3) % 16)).collect();
        let hist = mismatch_type_distribution(Encoding::Mtmc, 5, &pairs);
        let sum: f64 = hist.fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(hist.total(), 16 * 5);
    }

    #[test]
    fn mtmc_near_pairs_never_mismatch3() {
        // Fig. 5(b): |a-b| < CL implies max mismatch <= 1.
        let rows = max_mismatch_by_distance(Encoding::Mtmc, 5);
        for row in &rows {
            if (row.distance as usize) < 5 {
                assert_eq!(row.prob[2], 0.0, "distance {}", row.distance);
                assert_eq!(row.prob[3], 0.0, "distance {}", row.distance);
            }
        }
    }

    #[test]
    fn b4e_small_distance_can_mismatch3() {
        // Fig. 3(b): B4E shows mismatch-3 even at distance 1 (3 vs 4).
        let rows = max_mismatch_by_distance(Encoding::B4e, 3);
        assert!(rows[1].prob[3] > 0.0);
    }

    #[test]
    fn distance_zero_is_all_mismatch0() {
        for enc in super::super::ALL_ENCODINGS {
            let rows = max_mismatch_by_distance(enc, 2);
            assert_eq!(rows[0].prob[0], 1.0, "{enc:?}");
        }
    }

    #[test]
    fn probabilities_sum_to_one() {
        for enc in [Encoding::B4e, Encoding::Mtmc] {
            for row in max_mismatch_by_distance(enc, 3) {
                if row.pairs > 0 {
                    let s: f64 = row.prob.iter().sum();
                    assert!((s - 1.0).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn b4e_mismatch3_fraction_grows_with_cl() {
        // Fig. 3(a)'s trend: longer B4E code words → more mismatch-3 mass
        // (uniform random pairs stand in for the embedding pairs here;
        // the artifact-driven version lives in experiments::fig3_5).
        let mut rng = crate::testutil::Rng::new(0xF16);
        let mut frac3 = |cl: usize| {
            let levels = Encoding::B4e.levels(cl);
            let pairs: Vec<(u32, u32)> = (0..4000)
                .map(|_| (rng.below(levels) as u32, rng.below(levels) as u32))
                .collect();
            mismatch_type_distribution(Encoding::B4e, cl, &pairs).fractions()[3]
        };
        assert!(frac3(4) > frac3(1) * 0.9, "mismatch-3 mass should not shrink");
    }
}
