//! Base-4 encoding (B4E) [18]: bit slicing into base-4 digits, least
//! significant digit first (matching `python/compile/encodings.py`).
//! Precision scales as `4^cl` but small value distances can produce
//! mismatch-3 words (e.g. 4 = `10` vs 3 = `03`), the bottleneck pathology
//! Fig. 3(b) of the paper quantifies.

/// Append the `cl` base-4 digits of `value`, LSB first.
pub fn encode_b4e(value: u32, cl: usize, out: &mut Vec<u8>) {
    let mut v = value;
    for _ in 0..cl {
        out.push((v % 4) as u8);
        v /= 4;
    }
    assert!(v == 0, "B4E value {value} needs more than {cl} digits");
}

/// Inverse of [`encode_b4e`].
pub fn decode_b4e(words: &[u8]) -> u32 {
    let mut value = 0u32;
    for (i, &w) in words.iter().enumerate() {
        value += (w as u32) << (2 * i);
    }
    value
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn table1_rows() {
        // Paper Table 1 (CL=2, printed MSB-first there): 7 -> "13".
        let mut out = Vec::new();
        encode_b4e(7, 2, &mut out);
        assert_eq!(out, vec![3, 1]); // LSB first
        out.clear();
        encode_b4e(12, 2, &mut out);
        assert_eq!(out, vec![0, 3]); // "30"
    }

    #[test]
    fn roundtrip() {
        forall(
            "b4e roundtrip",
            128,
            |rng| {
                let cl = 1 + rng.below(9);
                let value = rng.below(4usize.pow(cl as u32)) as u32;
                (cl, value)
            },
            |&(cl, value)| {
                let mut out = Vec::new();
                encode_b4e(value, cl, &mut out);
                decode_b4e(&out) == value
            },
        );
    }

    #[test]
    #[should_panic(expected = "needs more")]
    fn rejects_overflow() {
        encode_b4e(16, 2, &mut Vec::new());
    }

    #[test]
    fn vector_roundtrip_through_encode_vector() {
        // Whole-vector round-trip: dimension-major encode_vector output
        // decodes per-dimension chunk back to the original values.
        use crate::encoding::Encoding;
        forall(
            "b4e encode_vector roundtrip",
            64,
            |rng| {
                let cl = 1 + rng.below(6);
                let dims = 1 + rng.below(24);
                let values: Vec<u32> = (0..dims)
                    .map(|_| rng.below(4usize.pow(cl as u32)) as u32)
                    .collect();
                (cl, values)
            },
            |&(cl, ref values)| {
                let words = Encoding::B4e.encode_vector(values, cl);
                words
                    .chunks(cl)
                    .zip(values)
                    .all(|(chunk, &v)| decode_b4e(chunk) == v)
            },
        );
    }
}
