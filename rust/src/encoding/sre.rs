//! Simple repetition encoding (SRE) [11]: the 4-level value duplicated
//! `cl` times. No precision gain — redundancy averages out device noise
//! in the voting scheme.

/// Append the SRE code words for `value` (must be `< 4`).
pub fn encode_sre(value: u32, cl: usize, out: &mut Vec<u8>) {
    assert!(value < 4, "SRE value {value} out of range");
    for _ in 0..cl {
        out.push(value as u8);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeats() {
        let mut out = Vec::new();
        encode_sre(2, 6, &mut out);
        assert_eq!(out, vec![2; 6]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_large_value() {
        encode_sre(4, 2, &mut Vec::new());
    }
}
