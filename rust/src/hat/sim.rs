//! Differentiable MCAM simulation for Hardware-Aware Training — the rust
//! mirror of `python/compile/mcam_sim.py` (paper §3.3, Fig. 8).
//!
//! Forward computes exactly what the device computes (hard fake-quant,
//! hard MTMC code words via [`crate::encoding::mtmc::encode_mtmc`], hard
//! SA threshold ladder via [`crate::device::sense::SenseLadder`]);
//! backward applies the paper's three straight-through estimators:
//!
//! * **fake-quant** — identity inside the clip range (jax `clip`
//!   convention: multiplier 1 inside, 0.5 at an exact boundary, 0
//!   outside);
//! * **MTMC encode** — the Fig. 8(b) trend line of slope `1/CL`;
//! * **sense amplifier** — hard vote count forward, sigmoid derivative
//!   backward in log-current (Fig. 8(c), sharpness `sa_beta`).
//!
//! Device noise reuses [`crate::device::variation::VariationModel`]'s
//! lognormal cell factors, drawn from a caller-provided seeded
//! [`Rng`] in string-major order `(query, support, group, column, cell)`
//! — a fixed seed replays a noisy meta step bit-for-bit
//! (`rust/tests/test_hat_props.rs`).
//!
//! Parity note (DESIGN.md §HAT): the AVSS query word is
//! `round(clip(x)/q_step) * q_step / q_step` — *near*-integer f32, and
//! `d|q - s|` needs `sign(q - s)` evaluated on those exact bits. The
//! forward therefore replicates the python f32 arithmetic verbatim, and
//! the parity fixture injects python's f32 clip (`clip_override`) so
//! every rounding and sign decision is made on identical bits.

use super::tensor::Params;
use crate::device::sense::SenseLadder;
use crate::device::variation::VariationModel;
use crate::device::McamParams;
use crate::encoding::mtmc::encode_mtmc;
use crate::quant::CLIP_SIGMA;
use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// HAT simulation knobs (defaults follow the python `SimConfig`).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Support code word length (support levels = `3*cl + 1`).
    pub cl: usize,
    /// AVSS (4-level query, one word line drive) vs SVSS.
    pub asymmetric: bool,
    /// Lognormal device-variation sigma (0 = ideal device).
    pub noise_sigma: f64,
    /// SA sensing-ladder depth.
    pub n_thresholds: usize,
    /// Sigmoid sharpness of the SA backward pass.
    pub sa_beta: f64,
    pub params: McamParams,
    /// Testing/parity hook: use this f32 clip instead of calibrating
    /// from the episode embeddings.
    pub clip_override: Option<f32>,
}

impl SimConfig {
    pub fn new(cl: usize, asymmetric: bool) -> SimConfig {
        assert!(cl >= 1, "cl must be >= 1");
        SimConfig {
            cl,
            asymmetric,
            noise_sigma: 0.15,
            n_thresholds: 16,
            sa_beta: 40.0,
            params: McamParams::default(),
            clip_override: None,
        }
    }

    /// Disable device noise (the python `noise_key=None` path).
    pub fn ideal(mut self) -> SimConfig {
        self.noise_sigma = 0.0;
        self
    }

    pub fn levels(&self) -> usize {
        3 * self.cl + 1
    }
}

// ---------------------------------------------------------------------------
// straight-through building blocks
// ---------------------------------------------------------------------------

/// Fake-quantize one embedding value: `(snapped, d snapped/d x)` under
/// the STE. The multiplier is the jax `jnp.clip` gradient: 1 strictly
/// inside `[0, clip]`, 0.5 at an exact boundary (tied `max`/`min`), 0
/// outside.
pub fn fake_quant(x: f32, levels: usize, clip: f32) -> (f32, f32) {
    let step = clip / (levels - 1) as f32;
    let clipped = x.clamp(0.0, clip);
    let snapped = (clipped / step).round() * step;
    let grad = if x < 0.0 || x > clip {
        0.0
    } else if x == 0.0 || x == clip {
        0.5
    } else {
        1.0
    };
    (snapped, grad)
}

/// Hard MTMC words for a (near-integer) value in `[0, 3*cl]`, appended
/// to `out` as f32; gradient of each word w.r.t. the value is `1/cl`
/// (the Fig. 8(b) trend line — applied by the caller).
pub fn encode_value_words(value: f32, cl: usize, out: &mut Vec<f32>) {
    let v = (value.round() as u32).min(3 * cl as u32);
    let mut words = Vec::with_capacity(cl);
    encode_mtmc(v, cl, &mut words);
    out.extend(words.iter().map(|&w| w as f32));
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Multi-level sensing of one string current against a precomputed
/// `ln(threshold)` ladder: `(hard votes, d votes/d current)` where the
/// backward is the sigmoid derivative in log-current.
pub fn votes_and_grad(current: f64, ln_thresholds: &[f64], beta: f64) -> (u32, f64) {
    let ln_i = current.ln();
    let mut votes = 0u32;
    let mut dsum = 0.0f64;
    for &ln_t in ln_thresholds {
        let z = beta * (ln_i - ln_t);
        if z > 0.0 {
            votes += 1;
        }
        let s = sigmoid(z);
        dsum += beta * s * (1.0 - s);
    }
    (votes, dsum / current)
}

// ---------------------------------------------------------------------------
// full episode pipeline (what HAT back-propagates through)
// ---------------------------------------------------------------------------

/// Forward state of one simulated episode, retained for
/// [`episode_backward`].
pub struct EpisodeSim {
    pub n_way: usize,
    pub n_query: usize,
    pub n_support: usize,
    pub dims: usize,
    groups: usize,
    cl: usize,
    clq: usize,
    asymmetric: bool,
    /// Calibrated (or injected) f32 clip of this episode.
    pub clip: f32,
    sy: Vec<u32>,
    q_words: Vec<f32>,      // (Q, d, clq)
    s_words: Vec<f32>,      // (S, d, cl)
    q_value_grad: Vec<f32>, // (Q, d): d word-value / d embedding
    s_value_grad: Vec<f32>, // (S, d)
    resist: Vec<f32>,       // (Q, S, G, 24, cl) noisy cell resistances
    series: Vec<f32>,       // (Q, S, G, cl) f32 series sums (python order)
    dv_di: Vec<f64>,        // (Q, S, G, cl) sigmoid-backward d votes/d I
    /// Accumulated SA votes per (query, support) pair — integers in f32.
    pub votes: Vec<f32>,
    /// Raw class logits `(Q, n_way)`: max votes over each class's shots.
    pub logits: Vec<f32>,
}

/// Embeddings → fake-quant → MTMC encode → noisy MCAM strings → SA
/// votes → winner-take-all class logits. `sy` holds episode-local
/// support labels in `[0, n_way)`; `noise` draws one lognormal factor
/// per sensed cell when `cfg.noise_sigma > 0`.
pub fn episode_logits(
    q_emb: &[f32],
    s_emb: &[f32],
    dims: usize,
    sy: &[u32],
    n_way: usize,
    cfg: &SimConfig,
    mut noise: Option<&mut Rng>,
) -> EpisodeSim {
    assert!(dims > 0 && q_emb.len() % dims == 0 && s_emb.len() % dims == 0);
    let nq = q_emb.len() / dims;
    let ns = s_emb.len() / dims;
    assert_eq!(sy.len(), ns, "support label count mismatch");
    assert!(sy.iter().all(|&l| (l as usize) < n_way), "support label out of range");

    // Clip from episode statistics (stop-gradient in python; here simply
    // not differentiated). Concat order matches python: query first.
    let clip = cfg.clip_override.unwrap_or_else(|| {
        let n = (q_emb.len() + s_emb.len()) as f32;
        let mut sum = 0.0f32;
        for &v in q_emb.iter().chain(s_emb) {
            sum += v;
        }
        let mean = sum / n;
        let mut sq = 0.0f32;
        for &v in q_emb.iter().chain(s_emb) {
            sq += (v - mean) * (v - mean);
        }
        mean + CLIP_SIGMA as f32 * (sq / n).sqrt() + 1e-6
    });
    let levels = cfg.levels();
    let step = clip / (levels - 1) as f32;
    let cl = cfg.cl;

    // Support side: fake-quant to [0, 3cl] values, then hard MTMC words.
    let mut s_words = Vec::with_capacity(ns * dims * cl);
    let mut s_value_grad = vec![0.0f32; ns * dims];
    for (i, &x) in s_emb.iter().enumerate() {
        let (fq, gmul) = fake_quant(x, levels, clip);
        let value = fq / step;
        s_value_grad[i] = gmul / step;
        encode_value_words(value, cl, &mut s_words);
    }

    // Query side: 4-level single word (AVSS) or symmetric words (SVSS).
    let (clq, q_step) = if cfg.asymmetric { (1, clip / 3.0) } else { (cl, step) };
    let mut q_words = Vec::with_capacity(nq * dims * clq);
    let mut q_value_grad = vec![0.0f32; nq * dims];
    for (i, &x) in q_emb.iter().enumerate() {
        if cfg.asymmetric {
            let (fq, gmul) = fake_quant(x, 4, clip);
            // Keep the python f32 arithmetic: near-integer, not rounded.
            q_words.push(fq / q_step);
            q_value_grad[i] = gmul / q_step;
        } else {
            let (fq, gmul) = fake_quant(x, levels, clip);
            q_value_grad[i] = gmul / step;
            encode_value_words(fq / step, cl, &mut q_words);
        }
    }

    let groups = dims.div_ceil(CELLS_PER_STRING);
    let ladder = SenseLadder::new(&cfg.params, cfg.n_thresholds);
    let ln_thr: Vec<f64> = ladder.thresholds().iter().map(|&t| t.ln()).collect();
    let ln_alpha = cfg.params.alpha.ln();
    let variation = VariationModel { program_sigma: cfg.noise_sigma, read_sigma: 0.0 };

    let mut resist = vec![0.0f32; nq * ns * groups * CELLS_PER_STRING * cl];
    let mut series = vec![0.0f32; nq * ns * groups * cl];
    let mut dv_di = vec![0.0f64; nq * ns * groups * cl];
    let mut votes = vec![0.0f32; nq * ns];
    for qi in 0..nq {
        for si in 0..ns {
            let mut total = 0u32;
            for g in 0..groups {
                for c in 0..cl {
                    let str_idx = ((qi * ns + si) * groups + g) * cl + c;
                    let mut sum = 0.0f32;
                    for cell in 0..CELLS_PER_STRING {
                        let dim = g * CELLS_PER_STRING + cell;
                        let mismatch = if dim < dims {
                            let qw = q_words[(qi * dims + dim) * clq + c % clq];
                            let sw = s_words[(si * dims + dim) * cl + c];
                            (qw - sw).abs()
                        } else {
                            0.0 // match-all zero padding
                        };
                        let mut r =
                            (cfg.params.r0 * (mismatch as f64 * ln_alpha).exp()) as f32;
                        if let Some(rng) = noise.as_deref_mut() {
                            r *= variation.cell_factor(rng);
                        }
                        resist[str_idx * CELLS_PER_STRING + cell] = r;
                        sum += r;
                    }
                    series[str_idx] = sum;
                    let current = cfg.params.v_bl / sum as f64;
                    let (v, dv) = votes_and_grad(current, &ln_thr, cfg.sa_beta);
                    total += v;
                    dv_di[str_idx] = dv;
                }
            }
            votes[qi * ns + si] = total as f32;
        }
    }

    // Winner-take-all class logits: max votes over each class's shots.
    let mut logits = vec![f32::NEG_INFINITY; nq * n_way];
    for qi in 0..nq {
        for (si, &label) in sy.iter().enumerate() {
            let slot = qi * n_way + label as usize;
            if votes[qi * ns + si] > logits[slot] {
                logits[slot] = votes[qi * ns + si];
            }
        }
    }
    assert!(
        logits.iter().all(|v| v.is_finite()),
        "every class needs at least one support shot"
    );

    EpisodeSim {
        n_way,
        n_query: nq,
        n_support: ns,
        dims,
        groups,
        cl,
        clq,
        asymmetric: cfg.asymmetric,
        clip,
        sy: sy.to_vec(),
        q_words,
        s_words,
        q_value_grad,
        s_value_grad,
        resist,
        series,
        dv_di,
        votes,
        logits,
    }
}

/// Reverse pass of [`episode_logits`]: gradients w.r.t. the query and
/// support embeddings given `d_logits` over the raw `(Q, n_way)` class
/// logits. Max-over-shots splits the gradient evenly across exactly
/// tied winning shots (the jax `max` reduction rule); `d|q - s|` uses
/// `sign(q - s)` with `sign(0) = +1` (the jax `abs` rule).
pub fn episode_backward(
    sim: &EpisodeSim,
    cfg: &SimConfig,
    d_logits: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let (nq, ns, dims) = (sim.n_query, sim.n_support, sim.dims);
    assert_eq!(d_logits.len(), nq * sim.n_way);
    let ln_alpha = cfg.params.alpha.ln();

    // Route class-logit gradients to the winning shot(s).
    let mut d_votes = vec![0.0f64; nq * ns];
    for qi in 0..nq {
        for cls in 0..sim.n_way {
            let g = d_logits[qi * sim.n_way + cls] as f64;
            if g == 0.0 {
                continue;
            }
            let top = sim.logits[qi * sim.n_way + cls];
            let winners = sim
                .sy
                .iter()
                .enumerate()
                .filter(|&(si, &l)| l as usize == cls && sim.votes[qi * ns + si] == top)
                .map(|(si, _)| si)
                .collect::<Vec<_>>();
            let share = g / winners.len() as f64;
            for si in winners {
                d_votes[qi * ns + si] += share;
            }
        }
    }

    let mut d_q_value = vec![0.0f64; nq * dims];
    let mut d_s_value = vec![0.0f64; ns * dims];
    for qi in 0..nq {
        for si in 0..ns {
            let dv = d_votes[qi * ns + si];
            if dv == 0.0 {
                continue;
            }
            for g in 0..sim.groups {
                for c in 0..sim.cl {
                    let str_idx = ((qi * ns + si) * sim.groups + g) * sim.cl + c;
                    let series = sim.series[str_idx] as f64;
                    // dL/dI, then dI/dseries = -v_bl / series^2.
                    let d_series =
                        -dv * sim.dv_di[str_idx] * cfg.params.v_bl / (series * series);
                    for cell in 0..CELLS_PER_STRING {
                        let dim = g * CELLS_PER_STRING + cell;
                        if dim >= dims {
                            continue; // padding cells carry no gradient
                        }
                        let r = sim.resist[str_idx * CELLS_PER_STRING + cell] as f64;
                        let d_mismatch = d_series * r * ln_alpha;
                        let qw = sim.q_words[(qi * dims + dim) * sim.clq + c % sim.clq];
                        let sw = sim.s_words[(si * dims + dim) * sim.cl + c];
                        let sign = if qw >= sw { 1.0 } else { -1.0 };
                        let d_word = d_mismatch * sign;
                        if sim.asymmetric {
                            d_q_value[qi * dims + dim] += d_word;
                        } else {
                            d_q_value[qi * dims + dim] += d_word / sim.cl as f64;
                        }
                        d_s_value[si * dims + dim] -= d_word / sim.cl as f64;
                    }
                }
            }
        }
    }

    let d_q_emb: Vec<f32> = d_q_value
        .iter()
        .zip(&sim.q_value_grad)
        .map(|(&d, &g)| (d * g as f64) as f32)
        .collect();
    let d_s_emb: Vec<f32> = d_s_value
        .iter()
        .zip(&sim.s_value_grad)
        .map(|(&d, &g)| (d * g as f64) as f32)
        .collect();
    (d_q_emb, d_s_emb)
}

// ---------------------------------------------------------------------------
// standardized cross-entropy over the raw vote logits
// ---------------------------------------------------------------------------

/// HAT meta loss on raw vote logits: per-query standardization
/// `3 (L - mean)/(std + 1e-6)` (vote totals reach the hundreds; without
/// this the softmax saturates and the STE gradients vanish), then mean
/// cross-entropy. Returns the loss and `dL/d raw logits`.
pub fn standardized_cross_entropy(
    logits: &[f32],
    qy: &[u32],
    n_way: usize,
) -> (f32, Vec<f32>) {
    let nq = qy.len();
    assert_eq!(logits.len(), nq * n_way);
    let n = n_way as f32;
    let mut z = vec![0.0f32; logits.len()];
    let mut mus = vec![0.0f32; nq];
    let mut sds = vec![0.0f32; nq];
    for q in 0..nq {
        let row = &logits[q * n_way..(q + 1) * n_way];
        let mu = row.iter().sum::<f32>() / n;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
        let sd = var.sqrt();
        mus[q] = mu;
        sds[q] = sd;
        for c in 0..n_way {
            z[q * n_way + c] = 3.0 * (row[c] - mu) / (sd + 1e-6);
        }
    }
    let (loss, dz) = super::model::cross_entropy(&z, qy, n_way);
    // Backward through z = 3 (x - mu) / s with s = sigma + 1e-6:
    //   dx_j = 3/s (g_j - mean(g)) - 3 c_j / (n sigma s^2) * sum(g_i c_i)
    let mut d = vec![0.0f32; logits.len()];
    for q in 0..nq {
        let row = &logits[q * n_way..(q + 1) * n_way];
        let g = &dz[q * n_way..(q + 1) * n_way];
        let s = (sds[q] + 1e-6) as f64;
        let g_mean = g.iter().map(|&v| v as f64).sum::<f64>() / n_way as f64;
        let gc: f64 = g
            .iter()
            .zip(row)
            .map(|(&gi, &xi)| gi as f64 * (xi - mus[q]) as f64)
            .sum();
        for c in 0..n_way {
            let ci = (row[c] - mus[q]) as f64;
            let mut dx = 3.0 / s * (g[c] as f64 - g_mean);
            if sds[q] > 0.0 {
                dx -= 3.0 * ci * gc / (n_way as f64 * sds[q] as f64 * s * s);
            }
            d[q * n_way + c] = dx as f32;
        }
    }
    (loss, d)
}

/// Convenience wrapper asserting a parameter tree contains only
/// controller tensors (no classifier head) before a meta step.
pub fn assert_controller_params(params: &Params) {
    assert!(
        params.keys().all(|k| !k.starts_with("cls_")),
        "meta training operates on controller parameters only"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantSpec;

    #[test]
    fn fake_quant_matches_quant_module_states() {
        let (levels, clip) = (13usize, 2.5f32);
        let spec = QuantSpec::new(levels, clip as f64);
        for i in 0..200 {
            let x = -0.5 + i as f32 * 0.02;
            let (fq, _) = fake_quant(x, levels, clip);
            let state = (fq / (clip / (levels - 1) as f32)).round() as u32;
            assert_eq!(state, spec.quantize(x as f64), "x = {x}");
        }
    }

    #[test]
    fn fake_quant_grad_convention() {
        let clip = 3.0f32;
        assert_eq!(fake_quant(-0.1, 4, clip).1, 0.0);
        assert_eq!(fake_quant(0.0, 4, clip).1, 0.5);
        assert_eq!(fake_quant(1.3, 4, clip).1, 1.0);
        assert_eq!(fake_quant(clip, 4, clip).1, 0.5);
        assert_eq!(fake_quant(3.7, 4, clip).1, 0.0);
    }

    #[test]
    fn encode_words_match_mtmc_table() {
        let mut out = Vec::new();
        encode_value_words(7.0 + 1e-6, 4, &mut out); // near-integer input
        assert_eq!(out, vec![1.0, 1.0, 1.0, 2.0]);
    }

    #[test]
    fn votes_match_sense_ladder() {
        let params = McamParams::default();
        let ladder = SenseLadder::new(&params, 16);
        let ln_thr: Vec<f64> = ladder.thresholds().iter().map(|&t| t.ln()).collect();
        for i in 1..40 {
            let current = 0.001 * (1.2f64).powi(i);
            let (v, dv) = votes_and_grad(current, &ln_thr, 40.0);
            assert_eq!(v, ladder.votes(current), "current {current}");
            assert!(dv >= 0.0);
        }
    }

    fn toy_episode(asymmetric: bool, noise: Option<u64>) -> EpisodeSim {
        let dims = 6;
        let q: Vec<f32> = vec![0.1, 0.6, 1.2, 1.8, 2.4, 0.3, 0.2, 0.5, 1.1, 1.9, 2.2, 0.4];
        let s: Vec<f32> = vec![
            0.1, 0.6, 1.2, 1.8, 2.4, 0.3, 2.4, 0.1, 0.2, 0.3, 0.1, 2.2, 0.2, 0.5, 1.0, 1.7,
            2.3, 0.5, 1.1, 1.2, 1.3, 0.2, 0.9, 1.8,
        ];
        let sy = vec![0, 1, 0, 1];
        let mut cfg = SimConfig::new(4, asymmetric).ideal();
        if let Some(seed) = noise {
            cfg.noise_sigma = 0.15;
            let mut rng = Rng::new(seed);
            return episode_logits(&q, &s, dims, &sy, 2, &cfg, Some(&mut rng));
        }
        episode_logits(&q, &s, dims, &sy, 2, &cfg, None)
    }

    #[test]
    fn identical_support_wins_its_class() {
        // Query 0 equals support 0 exactly: the class-0 logit must be the
        // all-match vote ceiling and beat class 1.
        let sim = toy_episode(true, None);
        assert_eq!(sim.n_query, 2);
        let l0 = sim.logits[0];
        let l1 = sim.logits[1];
        assert!(l0 > l1, "{l0} vs {l1}");
    }

    #[test]
    fn votes_are_integer_valued() {
        let sim = toy_episode(false, None);
        for &v in &sim.votes {
            assert_eq!(v, v.round());
            assert!(v >= 0.0 && v <= (sim.groups * sim.cl * 16) as f32);
        }
    }

    #[test]
    fn noisy_forward_replays_bitwise() {
        let a = toy_episode(true, Some(42));
        let b = toy_episode(true, Some(42));
        assert_eq!(a.votes, b.votes);
        assert_eq!(a.resist, b.resist);
        let c = toy_episode(true, Some(43));
        assert!(a.resist != c.resist, "distinct seeds must draw distinct noise");
    }

    #[test]
    fn backward_shapes_and_tie_split() {
        let sim = toy_episode(true, None);
        let cfg = SimConfig::new(4, true).ideal();
        let d_logits = vec![1.0f32; sim.n_query * sim.n_way];
        let (dq, ds) = episode_backward(&sim, &cfg, &d_logits);
        assert_eq!(dq.len(), sim.n_query * sim.dims);
        assert_eq!(ds.len(), sim.n_support * sim.dims);
        assert!(dq.iter().chain(&ds).all(|v| v.is_finite()));
    }

    #[test]
    fn tied_winning_shots_split_the_gradient_evenly() {
        // Two bit-identical support shots in class 0: their vote totals
        // tie exactly, and the max-over-shots backward must give each
        // half the class gradient (the jax `max` reduction rule). The
        // parity fixture deliberately avoids ties (k_shot = 1), so this
        // convention is pinned here.
        let dims = 6;
        let q: Vec<f32> = vec![0.2, 0.6, 1.1, 1.7, 2.2, 0.4];
        let shot: Vec<f32> = vec![0.3, 0.5, 1.2, 1.6, 2.1, 0.5];
        let other: Vec<f32> = vec![2.0, 0.1, 0.2, 0.4, 0.3, 1.9];
        let s: Vec<f32> = [shot.clone(), shot, other].concat();
        let cfg = SimConfig::new(4, true).ideal();
        let sim = episode_logits(&q, &s, dims, &[0, 0, 1], 2, &cfg, None);
        assert_eq!(sim.votes[0], sim.votes[1], "identical shots must tie");
        let d_logits = vec![1.0f32, 0.0];
        let (_, ds) = episode_backward(&sim, &cfg, &d_logits);
        let (a, b) = (&ds[..dims], &ds[dims..2 * dims]);
        assert_eq!(a, b, "tied winners must receive identical gradients");
        assert!(a.iter().any(|&v| v != 0.0), "tied winners must receive gradient at all");
        // doubling one tied shot's logit gradient == routing it alone
        assert!(ds[2 * dims..].iter().all(|&v| v == 0.0), "losing class got gradient");
    }

    #[test]
    fn standardized_ce_gradient_sums_to_zero_per_row() {
        // The standardization removes the mean, so the backward gradient
        // of each query row must be (numerically) zero-sum.
        let logits = vec![40.0, 55.0, 47.0, 60.0, 41.0, 44.0];
        let qy = vec![1u32, 0u32];
        let (loss, d) = standardized_cross_entropy(&logits, &qy, 3);
        assert!(loss.is_finite() && loss > 0.0);
        for q in 0..2 {
            let sum: f32 = d[q * 3..(q + 1) * 3].iter().sum();
            assert!(sum.abs() < 1e-4, "row {q} gradient sum {sum}");
        }
    }

    #[test]
    fn clip_override_is_respected() {
        let mut cfg = SimConfig::new(4, true).ideal();
        cfg.clip_override = Some(7.5);
        let q = vec![0.5f32; 6];
        let s = vec![0.7f32; 12];
        let sim = episode_logits(&q, &s, 6, &[0, 1], 2, &cfg, None);
        assert_eq!(sim.clip, 7.5);
    }
}
