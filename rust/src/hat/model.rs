//! Conv4-family controllers: pure-rust forward **and** hand-derived
//! reverse-mode backward, mirroring `python/compile/model.py`.
//!
//! Layer stack per block: 3x3 SAME conv → ReLU → 2x2 max-pool (VALID),
//! then flatten → dense head → ReLU (embeddings are non-negative so the
//! MCAM quantizer covers `[0, clip]`).
//!
//! Gradient conventions follow jax exactly (pinned by the golden-parity
//! fixtures and the finite-difference checks in
//! `rust/tests/test_hat_props.rs`):
//!
//! * `relu'(0) == 0` (`jax.nn.relu`'s custom JVP);
//! * max-pool routes the incoming gradient to the **first** maximal
//!   element of the window in row-major order (`lax.reduce_window`'s
//!   select-and-scatter semantics);
//! * `l2_normalize` backward is `g/s - x (x·g)/(n s^2)` with
//!   `s = n + 1e-8` (an all-zero row falls back to `g/s` instead of the
//!   python `NaN` — the only documented divergence, unreachable under
//!   the fixture guards).
//!
//! All arithmetic is f32 (what XLA executes); accumulation order differs
//! from XLA, which is why parity is tolerance-based (DESIGN.md §HAT).

use super::tensor::{Params, Tensor};
use crate::testutil::Rng;

/// Static architecture description (mirror of the python
/// `ControllerConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControllerConfig {
    pub name: &'static str,
    pub image_hw: usize,
    pub channels: usize,
    pub n_blocks: usize,
    pub embed_dim: usize,
}

/// Conv4 with 48-d embeddings (the paper's Omniglot controller).
pub const OMNIGLOT_CONTROLLER: ControllerConfig = ControllerConfig {
    name: "conv4_omniglot",
    image_hw: 28,
    channels: 32,
    n_blocks: 4,
    embed_dim: 48,
};

/// Wider Conv4 with 480-d embeddings (ResNet12 stand-in, DESIGN.md §2).
pub const CUB_CONTROLLER: ControllerConfig = ControllerConfig {
    name: "conv4w_cub",
    image_hw: 32,
    channels: 64,
    n_blocks: 4,
    embed_dim: 480,
};

/// Budget controller for the rust-native synthetic training set
/// (`hat::data`) driven by the `train` CLI subcommand.
pub const SYNTH_CONTROLLER: ControllerConfig =
    ControllerConfig { name: "conv2_synth", image_hw: 12, channels: 8, n_blocks: 2, embed_dim: 16 };

impl ControllerConfig {
    /// Flattened feature size after `n_blocks` halvings.
    pub fn flat_dim(&self) -> usize {
        let mut hw = self.image_hw;
        for _ in 0..self.n_blocks {
            hw /= 2;
        }
        hw.max(1) * hw.max(1) * self.channels
    }

}

/// He-normal init (zero biases), drawing from the deterministic crate
/// [`Rng`]. Not draw-compatible with the jax init — python↔rust parity
/// runs start from fixture-supplied parameters instead.
pub fn init_controller(cfg: &ControllerConfig, rng: &mut Rng) -> Params {
    let mut params = Params::new();
    let mut cin = 1usize;
    for b in 0..cfg.n_blocks {
        let fan_in = 3 * 3 * cin;
        let std = (2.0 / fan_in as f64).sqrt();
        let n = 3 * 3 * cin * cfg.channels;
        let data: Vec<f32> = (0..n).map(|_| (std * rng.gaussian()) as f32).collect();
        params.insert(format!("conv{b}_w"), Tensor::new(vec![3, 3, cin, cfg.channels], data));
        params.insert(format!("conv{b}_b"), Tensor::zeros(&[cfg.channels]));
        cin = cfg.channels;
    }
    let flat = cfg.flat_dim();
    let std = (2.0 / flat as f64).sqrt();
    let data: Vec<f32> = (0..flat * cfg.embed_dim).map(|_| (std * rng.gaussian()) as f32).collect();
    params.insert("head_w".to_string(), Tensor::new(vec![flat, cfg.embed_dim], data));
    params.insert("head_b".to_string(), Tensor::zeros(&[cfg.embed_dim]));
    params
}

/// Linear classifier head over the embeddings (pretrain stage only).
pub fn init_classifier_head(cfg: &ControllerConfig, n_classes: usize, rng: &mut Rng) -> Params {
    let std = (2.0 / cfg.embed_dim as f64).sqrt();
    let data: Vec<f32> =
        (0..cfg.embed_dim * n_classes).map(|_| (std * rng.gaussian()) as f32).collect();
    let mut params = Params::new();
    params.insert("cls_w".to_string(), Tensor::new(vec![cfg.embed_dim, n_classes], data));
    params.insert("cls_b".to_string(), Tensor::zeros(&[n_classes]));
    params
}

// ---------------------------------------------------------------------------
// forward (with caches) + backward
// ---------------------------------------------------------------------------

struct BlockCache {
    in_h: usize,
    in_w: usize,
    in_c: usize,
    /// Input activations of the block's conv, `(B, in_h, in_w, in_c)`.
    conv_in: Vec<f32>,
    /// Post-ReLU pre-pool activations, `(B, in_h, in_w, channels)`.
    relu_out: Vec<f32>,
    /// Flat index into `relu_out` of each pooled element's argmax.
    argmax: Vec<usize>,
    out_h: usize,
    out_w: usize,
}

/// Activations retained by [`forward`] for the backward pass.
pub struct ForwardCache {
    batch: usize,
    blocks: Vec<BlockCache>,
    flat: Vec<f32>,
    /// Final embeddings (post-ReLU), `(B, embed_dim)`.
    pub emb: Vec<f32>,
}

/// 3x3 SAME convolution, NHWC x HWIO (f32 accumulation like XLA).
fn conv2d_same(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    cin: usize,
    weight: &Tensor,
    bias: &Tensor,
) -> Vec<f32> {
    let cout = weight.dims[3];
    debug_assert_eq!(weight.dims, vec![3, 3, cin, cout]);
    let mut out = vec![0.0f32; batch * h * w * cout];
    for n in 0..batch {
        for y in 0..h {
            for xx in 0..w {
                let out_base = ((n * h + y) * w + xx) * cout;
                for co in 0..cout {
                    let mut acc = bias.data[co];
                    for ky in 0..3 {
                        let iy = y as isize + ky as isize - 1;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..3 {
                            let ix = xx as isize + kx as isize - 1;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let in_base = ((n * h + iy as usize) * w + ix as usize) * cin;
                            let w_base = ((ky * 3 + kx) * cin) * cout + co;
                            for ci in 0..cin {
                                acc += x[in_base + ci] * weight.data[w_base + ci * cout];
                            }
                        }
                    }
                    out[out_base + co] = acc;
                }
            }
        }
    }
    out
}

/// 2x2/2 VALID max-pool; returns pooled values plus per-element argmax
/// (first maximum in row-major window order — the jax routing rule).
fn maxpool2(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<usize>, usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![0.0f32; batch * oh * ow * c];
    let mut argmax = vec![0usize; batch * oh * ow * c];
    for n in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut arg = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx = ((n * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ch;
                            if x[idx] > best {
                                best = x[idx];
                                arg = idx;
                            }
                        }
                    }
                    let o = ((n * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    argmax[o] = arg;
                }
            }
        }
    }
    (out, argmax, oh, ow)
}

/// Controller forward: `images` is `(B, hw, hw, 1)` row-major. Returns
/// the cache whose `emb` field holds the `(B, embed_dim)` embeddings.
pub fn forward(params: &Params, cfg: &ControllerConfig, images: &[f32]) -> ForwardCache {
    let hw = cfg.image_hw;
    assert_eq!(images.len() % (hw * hw), 0, "image batch size mismatch");
    let batch = images.len() / (hw * hw);
    let mut x = images.to_vec();
    let (mut h, mut w, mut cin) = (hw, hw, 1usize);
    let mut blocks = Vec::with_capacity(cfg.n_blocks);
    for b in 0..cfg.n_blocks {
        let weight = &params[&format!("conv{b}_w")];
        let bias = &params[&format!("conv{b}_b")];
        let mut conv = conv2d_same(&x, batch, h, w, cin, weight, bias);
        for v in conv.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let (pooled, argmax, oh, ow) = maxpool2(&conv, batch, h, w, cfg.channels);
        assert!(
            oh >= 1 && ow >= 1,
            "controller {}: spatial size collapsed to zero at block {b} — \
             image_hw {} supports at most {} halvings",
            cfg.name,
            cfg.image_hw,
            cfg.image_hw.ilog2()
        );
        blocks.push(BlockCache {
            in_h: h,
            in_w: w,
            in_c: cin,
            conv_in: x,
            relu_out: conv,
            argmax,
            out_h: oh,
            out_w: ow,
        });
        x = pooled;
        h = oh;
        w = ow;
        cin = cfg.channels;
    }
    let flat = x;
    let head_w = &params["head_w"];
    let head_b = &params["head_b"];
    let fdim = cfg.flat_dim();
    assert_eq!(flat.len(), batch * fdim, "flatten size mismatch");
    let mut emb = vec![0.0f32; batch * cfg.embed_dim];
    for n in 0..batch {
        for e in 0..cfg.embed_dim {
            let mut acc = head_b.data[e];
            for f in 0..fdim {
                acc += flat[n * fdim + f] * head_w.data[f * cfg.embed_dim + e];
            }
            emb[n * cfg.embed_dim + e] = if acc > 0.0 { acc } else { 0.0 };
        }
    }
    ForwardCache { batch, blocks, flat, emb }
}

/// Controller backward: gradients w.r.t. every parameter given
/// `d_emb = dL/d embeddings` (post-ReLU seam).
pub fn backward(
    params: &Params,
    cfg: &ControllerConfig,
    cache: &ForwardCache,
    d_emb: &[f32],
) -> Params {
    let batch = cache.batch;
    let fdim = cfg.flat_dim();
    assert_eq!(d_emb.len(), batch * cfg.embed_dim);
    let mut grads = Params::new();

    // head dense (+ its ReLU: emb > 0 iff pre-activation > 0)
    let head_w = &params["head_w"];
    let mut d_head_w = Tensor::zeros(&[fdim, cfg.embed_dim]);
    let mut d_head_b = Tensor::zeros(&[cfg.embed_dim]);
    let mut d_flat = vec![0.0f32; batch * fdim];
    for n in 0..batch {
        for e in 0..cfg.embed_dim {
            let alive = cache.emb[n * cfg.embed_dim + e] > 0.0;
            let g = if alive { d_emb[n * cfg.embed_dim + e] } else { 0.0 };
            if g == 0.0 {
                continue;
            }
            d_head_b.data[e] += g;
            for f in 0..fdim {
                d_head_w.data[f * cfg.embed_dim + e] += cache.flat[n * fdim + f] * g;
                d_flat[n * fdim + f] += head_w.data[f * cfg.embed_dim + e] * g;
            }
        }
    }
    grads.insert("head_w".to_string(), d_head_w);
    grads.insert("head_b".to_string(), d_head_b);

    // blocks in reverse: unpool -> relu mask -> conv backward
    let mut d_out = d_flat;
    for b in (0..cfg.n_blocks).rev() {
        let blk = &cache.blocks[b];
        let (h, w, cin) = (blk.in_h, blk.in_w, blk.in_c);
        let cout = cfg.channels;
        let mut d_relu = vec![0.0f32; batch * h * w * cout];
        for (o, &arg) in blk.argmax.iter().enumerate() {
            d_relu[arg] += d_out[o];
        }
        for (i, g) in d_relu.iter_mut().enumerate() {
            if blk.relu_out[i] <= 0.0 {
                *g = 0.0;
            }
        }
        let weight = &params[&format!("conv{b}_w")];
        let mut d_w = Tensor::zeros(&[3, 3, cin, cout]);
        let mut d_b = Tensor::zeros(&[cout]);
        let mut d_in = vec![0.0f32; batch * h * w * cin];
        for n in 0..batch {
            for y in 0..h {
                for xx in 0..w {
                    let out_base = ((n * h + y) * w + xx) * cout;
                    for co in 0..cout {
                        let g = d_relu[out_base + co];
                        if g == 0.0 {
                            continue;
                        }
                        d_b.data[co] += g;
                        for ky in 0..3 {
                            let iy = y as isize + ky as isize - 1;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..3 {
                                let ix = xx as isize + kx as isize - 1;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let in_base = ((n * h + iy as usize) * w + ix as usize) * cin;
                                let w_base = ((ky * 3 + kx) * cin) * cout + co;
                                for ci in 0..cin {
                                    d_w.data[w_base + ci * cout] += blk.conv_in[in_base + ci] * g;
                                    d_in[in_base + ci] += weight.data[w_base + ci * cout] * g;
                                }
                            }
                        }
                    }
                }
            }
        }
        grads.insert(format!("conv{b}_w"), d_w);
        grads.insert(format!("conv{b}_b"), d_b);
        d_out = d_in;
    }
    grads
}

// ---------------------------------------------------------------------------
// classifier head, losses, normalization
// ---------------------------------------------------------------------------

/// `logits = emb @ cls_w + cls_b` for the pretrain classifier.
pub fn apply_classifier(head: &Params, emb: &[f32], embed_dim: usize) -> Vec<f32> {
    let cls_w = &head["cls_w"];
    let cls_b = &head["cls_b"];
    let n_classes = cls_b.data.len();
    let batch = emb.len() / embed_dim;
    let mut logits = vec![0.0f32; batch * n_classes];
    for n in 0..batch {
        for c in 0..n_classes {
            let mut acc = cls_b.data[c];
            for e in 0..embed_dim {
                acc += emb[n * embed_dim + e] * cls_w.data[e * n_classes + c];
            }
            logits[n * n_classes + c] = acc;
        }
    }
    logits
}

/// Mean cross-entropy over rows plus `dL/dlogits` (`(softmax - 1y)/B`,
/// the log-softmax backward jax emits). Stable via per-row max shift.
pub fn cross_entropy(logits: &[f32], labels: &[u32], n_classes: usize) -> (f32, Vec<f32>) {
    let batch = labels.len();
    assert_eq!(logits.len(), batch * n_classes);
    let mut d = vec![0.0f32; logits.len()];
    let mut loss = 0.0f32;
    for n in 0..batch {
        let row = &logits[n * n_classes..(n + 1) * n_classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &l in row {
            sum += (l - max).exp();
        }
        let lse = max + sum.ln();
        loss += -(row[labels[n] as usize] - lse);
        for c in 0..n_classes {
            let softmax = (row[c] - max).exp() / sum;
            d[n * n_classes + c] =
                (softmax - if c as u32 == labels[n] { 1.0 } else { 0.0 }) / batch as f32;
        }
    }
    (loss / batch as f32, d)
}

/// Row-wise `x / (||x|| + 1e-8)`.
pub fn l2_normalize(x: &[f32], dim: usize) -> Vec<f32> {
    let mut out = x.to_vec();
    for row in out.chunks_mut(dim) {
        let n = row.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let s = n + 1e-8;
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    out
}

/// Backward of [`l2_normalize`]: `dx = g/s - x (x·g)/(n s^2)`.
pub fn l2_normalize_backward(x: &[f32], g: &[f32], dim: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for r in 0..x.len() / dim {
        let xs = &x[r * dim..(r + 1) * dim];
        let gs = &g[r * dim..(r + 1) * dim];
        let n = xs.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let s = n + 1e-8;
        if n == 0.0 {
            for i in 0..dim {
                out[r * dim + i] = gs[i] / s;
            }
            continue;
        }
        let dot: f32 = xs.iter().zip(gs).map(|(&a, &b)| a * b).sum();
        for i in 0..dim {
            out[r * dim + i] = gs[i] / s - xs[i] * dot / (n * s * s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ControllerConfig {
        ControllerConfig { name: "tiny", image_hw: 8, channels: 3, n_blocks: 2, embed_dim: 4 }
    }

    #[test]
    fn flat_dims() {
        assert_eq!(OMNIGLOT_CONTROLLER.flat_dim(), 32); // 28 -> 14 -> 7 -> 3 -> 1
        assert_eq!(CUB_CONTROLLER.flat_dim(), 2 * 2 * 64);
        assert_eq!(tiny_cfg().flat_dim(), 2 * 2 * 3);
    }

    #[test]
    fn forward_shapes_and_nonnegative() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let params = init_controller(&cfg, &mut rng);
        let images: Vec<f32> = (0..2 * 64).map(|_| rng.next_f64() as f32).collect();
        let cache = forward(&params, &cfg, &images);
        assert_eq!(cache.emb.len(), 2 * cfg.embed_dim);
        assert!(cache.emb.iter().all(|&v| v >= 0.0 && v.is_finite()));
    }

    #[test]
    fn pool_routes_to_first_max() {
        // window [[1, 1], [1, 0.5]] must route to element (0, 0).
        let x = vec![1.0, 1.0, 1.0, 0.5];
        let (out, argmax, oh, ow) = maxpool2(&x, 1, 2, 2, 1);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(out, vec![1.0]);
        assert_eq!(argmax, vec![0]);
    }

    #[test]
    fn odd_dims_drop_last_row_col() {
        // 3x3 -> 1x1 (VALID pooling ignores the trailing row/column).
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let (out, _, oh, ow) = maxpool2(&x, 1, 3, 3, 1);
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(out, vec![4.0]); // max of [[0,1],[3,4]]
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let (loss, d) = cross_entropy(&[0.0, 0.0, 0.0, 0.0], &[2], 4);
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        assert!((d[2] - (0.25 - 1.0)).abs() < 1e-6);
        assert!((d[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn l2_normalize_unit_norm() {
        let x = vec![3.0, 4.0];
        let y = l2_normalize(&x, 2);
        let n = (y[0] * y[0] + y[1] * y[1]).sqrt();
        assert!((n - 1.0).abs() < 1e-5);
    }

    #[test]
    fn l2_backward_orthogonal_to_x() {
        // d||x||-invariant direction: gradient of y wrt x is orthogonal
        // to x when contracted with x (up to the eps regulariser).
        let x = vec![0.6, -1.2, 0.3];
        let g = vec![0.5, 0.25, -1.0];
        let dx = l2_normalize_backward(&x, &g, 3);
        let dot: f32 = dx.iter().zip(&x).map(|(&a, &b)| a * b).sum();
        assert!(dot.abs() < 1e-5, "x·dx = {dot}");
    }
}
