//! Hand-rolled Adam, a faithful port of `adam_init` / `adam_update` in
//! `python/compile/model.py` (no optax in either image).
//!
//! Semantics pinned by the `adam_trace` block of
//! `rust/tests/fixtures/hat_parity.json`: f32 elementwise moments, the
//! python-side bias corrections `1 / (1 - b^t)` computed in f64 and then
//! applied in f32, and the `eps` added *outside* the square root —
//! exactly like the python reference.

use super::tensor::{zeros_like, Params};

pub const ADAM_B1: f64 = 0.9;
pub const ADAM_B2: f64 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// First/second moment estimates plus the step counter.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Params,
    pub v: Params,
    pub t: u32,
}

/// Fresh all-zero optimizer state for a parameter tree.
pub fn adam_init(params: &Params) -> AdamState {
    AdamState { m: zeros_like(params), v: zeros_like(params), t: 0 }
}

/// One Adam step in place. `grads` must cover every parameter tensor.
pub fn adam_update(params: &mut Params, grads: &Params, state: &mut AdamState, lr: f64) {
    state.t += 1;
    let b1 = ADAM_B1 as f32;
    let b2 = ADAM_B2 as f32;
    let mhat_scale = (1.0 / (1.0 - ADAM_B1.powi(state.t as i32))) as f32;
    let vhat_scale = (1.0 / (1.0 - ADAM_B2.powi(state.t as i32))) as f32;
    let lr = lr as f32;
    for (name, p) in params.iter_mut() {
        let g = grads.get(name).unwrap_or_else(|| panic!("adam: missing grad for {name:?}"));
        assert_eq!(g.dims, p.dims, "adam: grad shape mismatch for {name:?}");
        let m = state.m.get_mut(name).expect("adam state out of sync");
        let v = state.v.get_mut(name).expect("adam state out of sync");
        for i in 0..p.data.len() {
            m.data[i] = b1 * m.data[i] + (1.0 - b1) * g.data[i];
            v.data[i] = b2 * v.data[i] + (1.0 - b2) * g.data[i] * g.data[i];
            p.data[i] -=
                lr * (m.data[i] * mhat_scale) / ((v.data[i] * vhat_scale).sqrt() + ADAM_EPS);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hat::tensor::Tensor;

    fn one_param(values: &[f32]) -> Params {
        [("w".to_string(), Tensor::new(vec![values.len()], values.to_vec()))].into()
    }

    #[test]
    fn first_step_moves_by_about_lr() {
        // With zero state, m-hat/sqrt(v-hat) == g/|g|: the first step is
        // (almost exactly) +-lr per element, the property the parity
        // tolerances in test_hat_parity.rs are designed around.
        let mut p = one_param(&[1.0, -2.0]);
        let g = one_param(&[0.5, -0.25]);
        let mut st = adam_init(&p);
        adam_update(&mut p, &g, &mut st, 1e-3);
        assert!((p["w"].data[0] - (1.0 - 1e-3)).abs() < 1e-6);
        assert!((p["w"].data[1] - (-2.0 + 1e-3)).abs() < 1e-6);
        assert_eq!(st.t, 1);
    }

    #[test]
    fn zero_grad_is_a_noop() {
        let mut p = one_param(&[0.75]);
        let g = one_param(&[0.0]);
        let mut st = adam_init(&p);
        adam_update(&mut p, &g, &mut st, 1e-2);
        assert_eq!(p["w"].data[0], 0.75);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut p = one_param(&[0.1, 0.2, 0.3]);
            let mut st = adam_init(&p);
            for t in 0..5 {
                let g = one_param(&[0.1 * t as f32, -0.05, 0.02 * t as f32]);
                adam_update(&mut p, &g, &mut st, 1e-3);
            }
            p["w"].data.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    #[should_panic(expected = "missing grad")]
    fn missing_grad_panics() {
        let mut p = one_param(&[1.0]);
        let mut st = adam_init(&p);
        adam_update(&mut p, &Params::new(), &mut st, 1e-3);
    }
}
