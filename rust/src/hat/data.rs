//! Rust-native synthetic few-shot image data for the `train` CLI path.
//!
//! The python build pipeline renders SynthOmniglot/SynthCUB; this module
//! is the dependency-free stand-in that lets the rust stack train,
//! calibrate, and refresh support sets without a python sidecar
//! (ROADMAP north star). Classes are smooth sinusoidal textures with a
//! per-class signature and per-sample jitter — the same recipe as the
//! fixture dataset in `python/compile/dump_fixtures.py`.
//!
//! Images are flattened into an [`EmbeddingDataset`] with
//! `dims == hw * hw`, so [`crate::fsl::sample_episode`] draws train
//! episodes through exactly the sampler the eval harnesses use (one
//! seed-derivation scheme for train and eval — DESIGN.md §HAT).

use crate::fsl::EmbeddingDataset;
use crate::testutil::{derive_seed, Rng};

/// Shape of a synthetic dataset split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SynthSpec {
    pub hw: usize,
    pub train_classes: usize,
    pub test_classes: usize,
    pub per_class: usize,
}

impl SynthSpec {
    /// Budgeted default: enough classes for 4-way episodes per split.
    pub fn default_spec() -> SynthSpec {
        SynthSpec { hw: 12, train_classes: 10, test_classes: 6, per_class: 8 }
    }

    /// Tiny shape for smoke tests and CI.
    pub fn smoke() -> SynthSpec {
        SynthSpec { hw: 12, train_classes: 5, test_classes: 4, per_class: 6 }
    }
}

/// Train/test splits of flattened images (`dims = hw * hw`, pixel
/// values in `[0.05, 1]`), with class-local labels per split.
#[derive(Debug, Clone)]
pub struct SynthData {
    pub spec: SynthSpec,
    pub train: EmbeddingDataset,
    pub test: EmbeddingDataset,
}

fn render_class(spec: &SynthSpec, rng: &mut Rng, out: &mut Vec<f32>) {
    let hw = spec.hw;
    // Per-class signature: three sinusoidal modes.
    let modes: Vec<(f64, f64, f64, f64)> = (0..3)
        .map(|_| {
            (
                rng.range_f64(0.5, 2.5),
                rng.range_f64(0.5, 2.5),
                rng.range_f64(0.0, std::f64::consts::TAU),
                rng.range_f64(0.5, 1.0),
            )
        })
        .collect();
    let mut base = vec![0.0f64; hw * hw];
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for y in 0..hw {
        for x in 0..hw {
            let mut v = 0.0;
            for &(fx, fy, phase, amp) in &modes {
                let arg = std::f64::consts::TAU * (fx * x as f64 + fy * y as f64) / hw as f64;
                v += amp * (arg + phase).sin();
            }
            base[y * hw + x] = v;
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let span = (hi - lo).max(1e-9);
    for _ in 0..spec.per_class {
        for &b in &base {
            let norm = (b - lo) / span;
            let jittered = (0.8 * norm + 0.08 * rng.gaussian()).clamp(0.0, 1.0);
            out.push((0.05 + 0.95 * jittered) as f32);
        }
    }
}

/// Stream salt separating the data generator from every other consumer
/// of a run's seed (engine shards derive `derive_seed(seed, shard)`, so
/// unsalted class streams would be bitwise identical to device noise in
/// a train-then-eval run sharing one seed).
const DATA_STREAM: u64 = 0x11A7_0003;

/// Deterministically generate both splits; every class derives its own
/// RNG stream via [`derive_seed`], so splits are stable regardless of
/// generation order.
pub fn generate(spec: SynthSpec, seed: u64) -> SynthData {
    let dims = spec.hw * spec.hw;
    let data_seed = derive_seed(seed, DATA_STREAM);
    let mut build = |first_class: usize, n_classes: usize| {
        let mut data = Vec::with_capacity(n_classes * spec.per_class * dims);
        let mut labels = Vec::with_capacity(n_classes * spec.per_class);
        for local in 0..n_classes {
            let mut rng = Rng::new(derive_seed(data_seed, (first_class + local) as u64));
            render_class(&spec, &mut rng, &mut data);
            labels.extend((0..spec.per_class).map(|_| local as u32));
        }
        EmbeddingDataset::new(dims, data, labels)
    };
    let train = build(0, spec.train_classes);
    let test = build(spec.train_classes, spec.test_classes);
    SynthData { spec, train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let spec = SynthSpec::smoke();
        let data = generate(spec, 7);
        assert_eq!(data.train.len(), spec.train_classes * spec.per_class);
        assert_eq!(data.test.len(), spec.test_classes * spec.per_class);
        assert_eq!(data.train.dims, spec.hw * spec.hw);
        for row in 0..data.train.len() {
            for &v in data.train.embedding(row) {
                assert!((0.05..=1.0).contains(&(v as f64)), "pixel {v}");
            }
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = generate(SynthSpec::smoke(), 1);
        let b = generate(SynthSpec::smoke(), 1);
        let c = generate(SynthSpec::smoke(), 2);
        assert_eq!(a.train.embedding(0), b.train.embedding(0));
        assert_ne!(a.train.embedding(0), c.train.embedding(0));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class samples must be closer (on average) than
        // cross-class samples, otherwise training has no signal.
        let data = generate(SynthSpec::smoke(), 3);
        let ds = &data.train;
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).abs()).sum()
        };
        let per = data.spec.per_class;
        let within = dist(ds.embedding(0), ds.embedding(1));
        let across = dist(ds.embedding(0), ds.embedding(per));
        assert!(within < across, "within {within} across {across}");
    }
}
