//! Dense f32 tensors and named parameter trees for the HAT trainer.
//!
//! The training subsystem works on flat maps `name → Tensor` (the rust
//! mirror of the python parameter dicts in `python/compile/model.py`).
//! A [`std::collections::BTreeMap`] keeps iteration order deterministic,
//! which makes seeded training runs and the Adam update replay
//! bit-for-bit (`rust/tests/test_hat_props.rs`).

use std::collections::BTreeMap;

/// A dense row-major f32 tensor with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "tensor shape {dims:?} does not match {} elements",
            data.len()
        );
        Tensor { dims, data }
    }

    pub fn zeros(dims: &[usize]) -> Tensor {
        Tensor { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// A named parameter (or gradient) tree.
pub type Params = BTreeMap<String, Tensor>;

/// Zero tensors with the same names and shapes as `params`.
pub fn zeros_like(params: &Params) -> Params {
    params.iter().map(|(k, t)| (k.clone(), Tensor::zeros(&t.dims))).collect()
}

/// Elementwise `into += from` over matching trees (gradient accumulation
/// across the support and query backward passes of a meta step).
pub fn accumulate(into: &mut Params, from: &Params) {
    for (name, src) in from {
        let dst = into.get_mut(name).unwrap_or_else(|| panic!("missing grad tensor {name:?}"));
        assert_eq!(dst.dims, src.dims, "grad shape mismatch for {name:?}");
        for (d, s) in dst.data.iter_mut().zip(&src.data) {
            *d += s;
        }
    }
}

/// True when any pair of same-named tensors differs (used by the
/// training smoke checks: a meta step must move the parameters).
pub fn params_differ(a: &Params, b: &Params) -> bool {
    a.iter().any(|(k, t)| b.get(k).map(|u| u.data != t.data).unwrap_or(true))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn accumulate_adds() {
        let mut a: Params = [("w".to_string(), Tensor::new(vec![2], vec![1.0, 2.0]))].into();
        let b: Params = [("w".to_string(), Tensor::new(vec![2], vec![0.5, -1.0]))].into();
        accumulate(&mut a, &b);
        assert_eq!(a["w"].data, vec![1.5, 1.0]);
        assert!(params_differ(&a, &b));
        assert!(!params_differ(&a, &a.clone()));
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let a: Params = [("w".to_string(), Tensor::new(vec![2, 2], vec![1.0; 4]))].into();
        let z = zeros_like(&a);
        assert_eq!(z["w"].dims, vec![2, 2]);
        assert!(z["w"].data.iter().all(|&x| x == 0.0));
    }
}
