//! Hardware-Aware Training (HAT) — the paper's §3.3 two-stage controller
//! training, ported to pure rust (mirror of `python/compile/hat.py`).
//!
//! **Stage 1 — pretrain**: controller + linear classifier minimise
//! cross-entropy over all training classes (Adam, hand-derived
//! backprop — [`model`], [`adam`]).
//!
//! **Stage 2 — meta-train**, three variants sharing the stage-1 weights:
//!
//! | variant    | quantization        | device model                        |
//! |------------|---------------------|-------------------------------------|
//! | `std`      | none                | none (cosine prototypical logits)   |
//! | `hat_svss` | symmetric fake-quant| noisy MCAM sim, sigmoid-backward SA |
//! | `hat_avss` | asymmetric (query 4)| noisy MCAM sim, sigmoid-backward SA |
//!
//! The simulated device ([`sim`]) reuses the L3 constants end-to-end:
//! [`crate::device::McamParams`], the MTMC encoder, the SA ladder, and
//! [`crate::device::variation::VariationModel`]'s lognormal noise with
//! seed-derived streams — so controllers are trained against the same
//! physics the serving engine executes.
//!
//! Episodes are drawn through [`crate::fsl::sample_episode`] with the
//! shared [`crate::fsl::episode_rng`] seed-derivation scheme (one scheme
//! for train and eval; `rust/tests/test_determinism.rs` pins it), and
//! trained weights flow into [`crate::fsl::store`] artifacts via
//! [`export_artifacts`], where `experiments::{fig7, fig9, table2}`
//! accuracy rows consume them.
//!
//! Python↔rust parity is pinned by `rust/tests/test_hat_parity.rs`
//! against `rust/tests/fixtures/hat_parity.json` within the f32
//! tolerances documented in DESIGN.md §HAT; gradient correctness by the
//! finite-difference checks in `rust/tests/test_hat_props.rs`.

pub mod adam;
pub mod data;
pub mod model;
pub mod sim;
pub mod tensor;

pub use adam::{adam_init, adam_update, AdamState};
pub use model::{ControllerConfig, CUB_CONTROLLER, OMNIGLOT_CONTROLLER, SYNTH_CONTROLLER};
pub use sim::SimConfig;
pub use tensor::{Params, Tensor};

use crate::config::TrainSettings;
use crate::fsl::{episode_rng, sample_episode, EmbeddingDataset};
use crate::testutil::{derive_seed, Rng};
use anyhow::{Context, Result};
use std::fmt;
use std::path::Path;

/// The three meta-training variants (order matches the python module).
pub const VARIANTS: [&str; 3] = ["std", "hat_svss", "hat_avss"];

/// Stream salts for [`derive_seed`]: pretrain batch sampling and
/// per-episode device-noise draws own decorrelated RNG streams, so the
/// episode stream itself ([`episode_rng`]) is consumption-independent.
const PRETRAIN_STREAM: u64 = 0x11A7_0001;
const NOISE_STREAM: u64 = 0x11A7_0002;

/// Typed meta-training variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Episodic meta-baseline: cosine prototypical logits, no hardware.
    Std,
    /// HAT with symmetric quantization (SVSS column of Table 2 / Fig 7).
    HatSvss,
    /// The paper's HAT: asymmetric quantization + MTMC + noisy MCAM.
    HatAvss,
}

impl Variant {
    pub fn from_name(name: &str) -> std::result::Result<Variant, HatError> {
        match name {
            "std" => Ok(Variant::Std),
            "hat_svss" => Ok(Variant::HatSvss),
            "hat_avss" => Ok(Variant::HatAvss),
            other => Err(HatError::UnknownVariant(other.to_string())),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Variant::Std => "std",
            Variant::HatSvss => "hat_svss",
            Variant::HatAvss => "hat_avss",
        }
    }

    /// Does this variant train against the simulated device?
    pub fn hardware_aware(self) -> bool {
        self != Variant::Std
    }
}

/// Typed training errors (mirrors the `ValueError`s of `test_hat.py`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HatError {
    UnknownVariant(String),
    Data(String),
}

impl fmt::Display for HatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HatError::UnknownVariant(name) => {
                write!(f, "unknown meta-training variant {name:?} (std | hat_svss | hat_avss)")
            }
            HatError::Data(msg) => write!(f, "training data error: {msg}"),
        }
    }
}

impl std::error::Error for HatError {}

// ---------------------------------------------------------------------------
// stage 1: pre-training
// ---------------------------------------------------------------------------

fn gather_rows(ds: &EmbeddingDataset, rows: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows.len() * ds.dims);
    for &row in rows {
        out.extend_from_slice(ds.embedding(row));
    }
    out
}

/// Loss + gradients of one pretrain batch (cross-entropy over all
/// classes) without applying the update — the seam the golden-parity
/// harness compares against the fixture's jax gradients.
pub fn pretrain_grads(
    bundle: &Params,
    cfg: &ControllerConfig,
    images: &[f32],
    labels: &[u32],
) -> (f32, Params) {
    let n_classes = bundle["cls_b"].data.len();
    let batch = labels.len();
    let cache = model::forward(bundle, cfg, images);
    let logits = model::apply_classifier(bundle, &cache.emb, cfg.embed_dim);
    let (loss, d_logits) = model::cross_entropy(&logits, labels, n_classes);

    // Classifier backward: logits = emb @ cls_w + cls_b.
    let cls_w = &bundle["cls_w"];
    let mut d_cls_w = Tensor::zeros(&[cfg.embed_dim, n_classes]);
    let mut d_cls_b = Tensor::zeros(&[n_classes]);
    let mut d_emb = vec![0.0f32; batch * cfg.embed_dim];
    for n in 0..batch {
        for c in 0..n_classes {
            let g = d_logits[n * n_classes + c];
            if g == 0.0 {
                continue;
            }
            d_cls_b.data[c] += g;
            for e in 0..cfg.embed_dim {
                d_cls_w.data[e * n_classes + c] += cache.emb[n * cfg.embed_dim + e] * g;
                d_emb[n * cfg.embed_dim + e] += cls_w.data[e * n_classes + c] * g;
            }
        }
    }

    let mut grads = model::backward(bundle, cfg, &cache, &d_emb);
    grads.insert("cls_w".to_string(), d_cls_w);
    grads.insert("cls_b".to_string(), d_cls_b);
    (loss, grads)
}

/// One pretrain step (gradients + Adam) on an explicit image batch;
/// exposed so the parity harness can replay the fixture's deterministic
/// batch schedule.
pub fn pretrain_step(
    bundle: &mut Params,
    state: &mut AdamState,
    cfg: &ControllerConfig,
    images: &[f32],
    labels: &[u32],
    lr: f64,
) -> f32 {
    let (loss, grads) = pretrain_grads(bundle, cfg, images, labels);
    adam_update(bundle, &grads, state, lr);
    loss
}

/// Stage-1 pretraining over a whole (image) dataset. Returns the trained
/// controller parameters (classifier head stripped, as in python) plus
/// the per-step loss trace.
pub fn pretrain(
    ds: &EmbeddingDataset,
    cfg: &ControllerConfig,
    settings: &TrainSettings,
    seed: u64,
    log: &mut dyn FnMut(String),
) -> (Params, Vec<f32>) {
    assert_eq!(ds.dims, cfg.image_hw * cfg.image_hw, "dataset/controller image size mismatch");
    let n_classes = ds.n_classes();
    let mut rng = Rng::new(derive_seed(seed, PRETRAIN_STREAM));
    let mut bundle = model::init_controller(cfg, &mut rng);
    bundle.extend(model::init_classifier_head(cfg, n_classes, &mut rng));
    let mut state = adam_init(&bundle);
    let mut losses = Vec::with_capacity(settings.pretrain_steps);
    for step in 0..settings.pretrain_steps {
        let idx: Vec<usize> = (0..settings.pretrain_bs).map(|_| rng.below(ds.len())).collect();
        let images = gather_rows(ds, &idx);
        let labels: Vec<u32> = idx.iter().map(|&row| ds.label(row)).collect();
        let loss = pretrain_step(&mut bundle, &mut state, cfg, &images, &labels, settings.lr);
        losses.push(loss);
        if step % 100 == 0 || step + 1 == settings.pretrain_steps {
            log(format!("[pretrain {}] step {step:4} loss {loss:.4}", cfg.name));
        }
    }
    bundle.retain(|k, _| !k.starts_with("cls_"));
    (bundle, losses)
}

// ---------------------------------------------------------------------------
// stage 2: meta-training
// ---------------------------------------------------------------------------

/// Loss + gradients of one meta episode without applying the update.
/// `noise` supplies the per-episode device-noise stream for the
/// hardware-aware variants (ignored by `std`).
pub fn meta_grads(
    params: &Params,
    cfg: &ControllerConfig,
    sim_cfg: &SimConfig,
    variant: Variant,
    sx: &[f32],
    sy: &[u32],
    qx: &[f32],
    qy: &[u32],
    n_way: usize,
    noise: Option<&mut Rng>,
) -> (f32, Params) {
    sim::assert_controller_params(params);
    let s_cache = model::forward(params, cfg, sx);
    let q_cache = model::forward(params, cfg, qx);
    let dim = cfg.embed_dim;

    let (loss, d_q_emb, d_s_emb) = match variant {
        Variant::Std => std_episode_loss(&q_cache.emb, &s_cache.emb, dim, sy, qy, n_way),
        Variant::HatSvss | Variant::HatAvss => {
            let sim =
                sim::episode_logits(&q_cache.emb, &s_cache.emb, dim, sy, n_way, sim_cfg, noise);
            let (loss, d_raw) = sim::standardized_cross_entropy(&sim.logits, qy, n_way);
            let (dq, dsup) = sim::episode_backward(&sim, sim_cfg, &d_raw);
            (loss, dq, dsup)
        }
    };

    let mut grads = model::backward(params, cfg, &q_cache, &d_q_emb);
    tensor::accumulate(&mut grads, &model::backward(params, cfg, &s_cache, &d_s_emb));
    (loss, grads)
}

/// One meta step (episode gradients + Adam) on explicit support/query
/// image batches.
pub fn meta_step(
    params: &mut Params,
    state: &mut AdamState,
    cfg: &ControllerConfig,
    sim_cfg: &SimConfig,
    variant: Variant,
    sx: &[f32],
    sy: &[u32],
    qx: &[f32],
    qy: &[u32],
    n_way: usize,
    meta_lr: f64,
    noise: Option<&mut Rng>,
) -> f32 {
    let (loss, grads) = meta_grads(params, cfg, sim_cfg, variant, sx, sy, qx, qy, n_way, noise);
    adam_update(params, &grads, state, meta_lr);
    loss
}

/// The `std` meta-baseline loss: cosine-similarity prototypical logits
/// at temperature 10 (hand-derived backward through both
/// `l2_normalize`s and the shot-mean prototypes). Returns
/// `(loss, d_query_emb, d_support_emb)`; public for the
/// finite-difference harness in `rust/tests/test_hat_props.rs` (this
/// loss is smooth, so end-to-end FD is valid — the hardware-aware
/// variants are checked per-STE-op instead).
pub fn std_episode_loss(
    q_emb: &[f32],
    s_emb: &[f32],
    dim: usize,
    sy: &[u32],
    qy: &[u32],
    n_way: usize,
) -> (f32, Vec<f32>, Vec<f32>) {
    let ns = sy.len();
    let nq = qy.len();
    let s_n = model::l2_normalize(s_emb, dim);
    let q_n = model::l2_normalize(q_emb, dim);

    let mut counts = vec![0.0f32; n_way];
    for &l in sy {
        counts[l as usize] += 1.0;
    }
    assert!(counts.iter().all(|&c| c > 0.0), "every class needs support shots");
    let mut proto = vec![0.0f32; n_way * dim];
    for (si, &l) in sy.iter().enumerate() {
        for i in 0..dim {
            proto[l as usize * dim + i] += s_n[si * dim + i];
        }
    }
    for c in 0..n_way {
        for i in 0..dim {
            proto[c * dim + i] /= counts[c];
        }
    }
    let proto_n = model::l2_normalize(&proto, dim);

    let mut logits = vec![0.0f32; nq * n_way];
    for q in 0..nq {
        for c in 0..n_way {
            let mut dot = 0.0f32;
            for i in 0..dim {
                dot += q_n[q * dim + i] * proto_n[c * dim + i];
            }
            logits[q * n_way + c] = 10.0 * dot;
        }
    }
    let (loss, d_logits) = model::cross_entropy(&logits, qy, n_way);

    let mut d_q_n = vec![0.0f32; nq * dim];
    let mut d_proto_n = vec![0.0f32; n_way * dim];
    for q in 0..nq {
        for c in 0..n_way {
            let g = 10.0 * d_logits[q * n_way + c];
            if g == 0.0 {
                continue;
            }
            for i in 0..dim {
                d_q_n[q * dim + i] += g * proto_n[c * dim + i];
                d_proto_n[c * dim + i] += g * q_n[q * dim + i];
            }
        }
    }
    let d_proto = model::l2_normalize_backward(&proto, &d_proto_n, dim);
    let mut d_s_n = vec![0.0f32; ns * dim];
    for (si, &l) in sy.iter().enumerate() {
        for i in 0..dim {
            d_s_n[si * dim + i] = d_proto[l as usize * dim + i] / counts[l as usize];
        }
    }
    let d_q_emb = model::l2_normalize_backward(q_emb, &d_q_n, dim);
    let d_s_emb = model::l2_normalize_backward(s_emb, &d_s_n, dim);
    (loss, d_q_emb, d_s_emb)
}

/// Stage-2 meta-training: episodes drawn with the shared
/// [`episode_rng`] scheme, one decorrelated noise stream per episode.
/// `ds` holds flattened training images (`dims == image_hw^2`).
pub fn meta_train(
    params: &Params,
    ds: &EmbeddingDataset,
    cfg: &ControllerConfig,
    settings: &TrainSettings,
    variant: &str,
    seed: u64,
    log: &mut dyn FnMut(String),
) -> std::result::Result<Params, HatError> {
    let variant = Variant::from_name(variant)?;
    if ds.dims != cfg.image_hw * cfg.image_hw {
        return Err(HatError::Data(format!(
            "dataset rows are {} floats, controller expects {}x{} images",
            ds.dims, cfg.image_hw, cfg.image_hw
        )));
    }
    if settings.n_way > ds.n_classes() {
        return Err(HatError::Data(format!(
            "{}-way episodes but dataset has {} classes",
            settings.n_way,
            ds.n_classes()
        )));
    }
    for class in ds.classes() {
        if ds.class_rows(class).len() < settings.k_shot + settings.n_query {
            return Err(HatError::Data(format!(
                "class {class} has {} samples, episodes need {}",
                ds.class_rows(class).len(),
                settings.k_shot + settings.n_query
            )));
        }
    }

    let mut params = params.clone();
    let mut state = adam_init(&params);
    let mut sim_cfg = SimConfig::new(settings.hat_cl, variant == Variant::HatAvss);
    sim_cfg.noise_sigma = settings.noise_sigma;
    let noise_seed = derive_seed(seed, NOISE_STREAM);
    for ep in 0..settings.meta_episodes {
        let mut erng = episode_rng(seed, ep as u64);
        let episode =
            sample_episode(ds, &mut erng, settings.n_way, settings.k_shot, settings.n_query);
        let sup_rows: Vec<usize> = episode.support.iter().map(|&(row, _)| row).collect();
        let qry_rows: Vec<usize> = episode.queries.iter().map(|&(row, _)| row).collect();
        let sx = gather_rows(ds, &sup_rows);
        let qx = gather_rows(ds, &qry_rows);
        let sy: Vec<u32> = episode.support.iter().map(|&(_, l)| l).collect();
        let qy: Vec<u32> = episode.queries.iter().map(|&(_, l)| l).collect();
        let mut noise_rng = Rng::new(derive_seed(noise_seed, ep as u64));
        let noise = if variant.hardware_aware() && sim_cfg.noise_sigma > 0.0 {
            Some(&mut noise_rng)
        } else {
            None
        };
        let loss = meta_step(
            &mut params,
            &mut state,
            cfg,
            &sim_cfg,
            variant,
            &sx,
            &sy,
            &qx,
            &qy,
            settings.n_way,
            settings.meta_lr,
            noise,
        );
        if ep % 40 == 0 || ep + 1 == settings.meta_episodes {
            log(format!("[meta {}] episode {ep:4} loss {loss:.4}", variant.name()));
        }
    }
    Ok(params)
}

// ---------------------------------------------------------------------------
// embedding export + persistence
// ---------------------------------------------------------------------------

/// Embed a full flattened-image set in batches (build-time only).
pub fn embed_all(params: &Params, cfg: &ControllerConfig, ds: &EmbeddingDataset) -> Vec<f32> {
    assert_eq!(ds.dims, cfg.image_hw * cfg.image_hw);
    let mut out = Vec::with_capacity(ds.len() * cfg.embed_dim);
    let batch = 256;
    let mut row = 0;
    while row < ds.len() {
        let hi = (row + batch).min(ds.len());
        let rows: Vec<usize> = (row..hi).collect();
        let images = gather_rows(ds, &rows);
        let cache = model::forward(params, cfg, &images);
        out.extend_from_slice(&cache.emb);
        row = hi;
    }
    out
}

/// Save a parameter tree as one `.mvt` tensor per entry plus an index
/// file; round-trips bitwise (`rust/tests/test_hat_props.rs`).
pub fn save_params(dir: &Path, params: &Params) -> Result<()> {
    use crate::util::binio::{write_tensor, Tensor as IoTensor};
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    let mut index = String::new();
    for (name, tensor) in params {
        let io = IoTensor::F32 { dims: tensor.dims.clone(), data: tensor.data.clone() };
        write_tensor(&dir.join(format!("{name}.mvt")), &io)?;
        index.push_str(name);
        index.push('\n');
    }
    std::fs::write(dir.join("params.txt"), index).context("write params index")?;
    Ok(())
}

/// Inverse of [`save_params`].
pub fn load_params(dir: &Path) -> Result<Params> {
    use crate::util::binio::read_tensor;
    let index = std::fs::read_to_string(dir.join("params.txt"))
        .with_context(|| format!("read params index in {}", dir.display()))?;
    let mut params = Params::new();
    for name in index.lines().filter(|l| !l.trim().is_empty()) {
        let tensor = read_tensor(&dir.join(format!("{name}.mvt")))?;
        let dims = tensor.dims().to_vec();
        let data = tensor.as_f32()?.to_vec();
        params.insert(name.to_string(), Tensor::new(dims, data));
    }
    Ok(params)
}

/// Export a trained controller's embeddings as a
/// [`crate::fsl::store::ArtifactStore`]-compatible tree: test-split
/// embeddings + labels, the train-split clip calibration, and the
/// manifest keys the experiment harnesses read. Returns the clip.
pub fn export_artifacts(
    root: &Path,
    dataset: &str,
    variant: &str,
    cfg: &ControllerConfig,
    params: &Params,
    synth: &data::SynthData,
) -> Result<f64> {
    use crate::fsl::store::ArtifactWriter;
    use crate::util::binio::Tensor as IoTensor;

    let train_emb = embed_all(params, cfg, &synth.train);
    let clip = crate::quant::calibrate_clip(&train_emb, crate::quant::CLIP_SIGMA);
    let test_emb = embed_all(params, cfg, &synth.test);

    let mut writer = ArtifactWriter::open(root)?;
    writer.write_tensor(
        &format!("data/emb_{dataset}_{variant}_test.mvt"),
        &IoTensor::F32 { dims: vec![synth.test.len(), cfg.embed_dim], data: test_emb },
    )?;
    let labels: Vec<i32> = (0..synth.test.len()).map(|r| synth.test.label(r) as i32).collect();
    writer.write_tensor(
        &format!("data/labels_{dataset}_test.mvt"),
        &IoTensor::I32 { dims: vec![labels.len()], data: labels },
    )?;
    writer.set(&format!("clip_{dataset}_{variant}"), &format!("{clip}"));
    writer.set(&format!("embed_dim_{dataset}"), &format!("{}", cfg.embed_dim));
    writer.set(&format!("image_hw_{dataset}"), &format!("{}", cfg.image_hw));
    writer.finish()?;
    Ok(clip)
}

// ---------------------------------------------------------------------------
// smoke harness (CI: `mcamvss train --smoke`)
// ---------------------------------------------------------------------------

/// Fast end-to-end check: pretrain on the synthetic set (loss must
/// decrease), then two meta steps per variant on one fixed episode
/// (ideal device so the repeat is deterministic). Every loss must be
/// finite and decreasing: strictly for the smooth `std` variant, and
/// non-exploding for the hardware-aware variants — their hard
/// (vote-quantized) forward is piecewise constant, so a single
/// 2e-4-sized step only decreases the *soft surrogate* the STE
/// gradients descend, not necessarily the integer-vote loss (DESIGN.md
/// §HAT). Returns a human-readable report.
pub fn smoke(seed: u64) -> Result<String> {
    let synth = data::generate(data::SynthSpec::smoke(), seed);
    let cfg = SYNTH_CONTROLLER;
    let settings = TrainSettings::synth().smoke();
    let mut report = String::new();

    let (pre, pre_losses) = pretrain(&synth.train, &cfg, &settings, seed, &mut |_| {});
    let (first, last) = (pre_losses[0], *pre_losses.last().unwrap());
    if !pre_losses.iter().all(|l| l.is_finite()) {
        anyhow::bail!("pretrain produced a non-finite loss");
    }
    if last >= first {
        anyhow::bail!("pretrain loss did not decrease: {first} -> {last}");
    }
    report.push_str(&format!("pretrain: loss {first:.4} -> {last:.4} ok\n"));

    let mut erng = episode_rng(seed, 0);
    let episode =
        sample_episode(&synth.train, &mut erng, settings.n_way, settings.k_shot, settings.n_query);
    let sup_rows: Vec<usize> = episode.support.iter().map(|&(row, _)| row).collect();
    let qry_rows: Vec<usize> = episode.queries.iter().map(|&(row, _)| row).collect();
    let sx = gather_rows(&synth.train, &sup_rows);
    let qx = gather_rows(&synth.train, &qry_rows);
    let sy: Vec<u32> = episode.support.iter().map(|&(_, l)| l).collect();
    let qy: Vec<u32> = episode.queries.iter().map(|&(_, l)| l).collect();

    for name in VARIANTS {
        let variant = Variant::from_name(name).expect("builtin variant");
        let mut params = pre.clone();
        let mut state = adam_init(&params);
        let sim_cfg = SimConfig::new(settings.hat_cl, variant == Variant::HatAvss).ideal();
        let mut losses = [0.0f32; 2];
        for slot in &mut losses {
            *slot = meta_step(
                &mut params,
                &mut state,
                &cfg,
                &sim_cfg,
                variant,
                &sx,
                &sy,
                &qx,
                &qy,
                settings.n_way,
                settings.meta_lr,
                None,
            );
        }
        if !losses.iter().all(|l| l.is_finite()) {
            anyhow::bail!("{name}: meta loss went non-finite: {losses:?}");
        }
        if variant == Variant::Std && losses[1] >= losses[0] {
            anyhow::bail!("{name}: meta loss did not decrease: {} -> {}", losses[0], losses[1]);
        }
        if variant != Variant::Std && losses[1] > losses[0] + 0.5 {
            anyhow::bail!("{name}: meta loss exploded: {} -> {}", losses[0], losses[1]);
        }
        if !tensor::params_differ(&params, &pre) {
            anyhow::bail!("{name}: meta step did not move the parameters");
        }
        report.push_str(&format!("meta {name}: loss {:.4} -> {:.4} ok\n", losses[0], losses[1]));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parsing() {
        assert_eq!(Variant::from_name("std").unwrap(), Variant::Std);
        assert_eq!(Variant::from_name("hat_avss").unwrap(), Variant::HatAvss);
        let err = Variant::from_name("bogus").unwrap_err();
        assert_eq!(err, HatError::UnknownVariant("bogus".to_string()));
        assert!(err.to_string().contains("bogus"));
        assert!(Variant::HatSvss.hardware_aware() && !Variant::Std.hardware_aware());
    }

    #[test]
    fn meta_train_rejects_unknown_variant() {
        let synth = data::generate(data::SynthSpec::smoke(), 1);
        let mut rng = Rng::new(1);
        let params = model::init_controller(&SYNTH_CONTROLLER, &mut rng);
        let settings = TrainSettings::synth().smoke();
        let err = meta_train(
            &params,
            &synth.train,
            &SYNTH_CONTROLLER,
            &settings,
            "bogus",
            1,
            &mut |_| {},
        )
        .unwrap_err();
        assert!(matches!(err, HatError::UnknownVariant(_)));
    }

    #[test]
    fn meta_train_rejects_bad_shapes() {
        let synth = data::generate(data::SynthSpec::smoke(), 1);
        let mut rng = Rng::new(1);
        let params = model::init_controller(&SYNTH_CONTROLLER, &mut rng);
        let mut settings = TrainSettings::synth().smoke();
        settings.n_way = 1000;
        let err = meta_train(
            &params,
            &synth.train,
            &SYNTH_CONTROLLER,
            &settings,
            "std",
            1,
            &mut |_| {},
        )
        .unwrap_err();
        assert!(matches!(err, HatError::Data(_)));
    }

    #[test]
    fn pretrain_learns_on_tiny_budget() {
        let synth = data::generate(data::SynthSpec::smoke(), 5);
        let settings = TrainSettings::synth().smoke();
        let (params, losses) = pretrain(&synth.train, &SYNTH_CONTROLLER, &settings, 5, &mut |_| {});
        assert!(!params.contains_key("cls_w"), "classifier must be stripped");
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses.last().unwrap() < &losses[0],
            "pretrain loss did not decrease: {:?}",
            (losses[0], losses.last().unwrap())
        );
    }

    #[test]
    fn params_roundtrip_bitwise() {
        let mut rng = Rng::new(9);
        let params = model::init_controller(&SYNTH_CONTROLLER, &mut rng);
        let dir = std::env::temp_dir().join(format!("hat_params_{}", std::process::id()));
        save_params(&dir, &params).unwrap();
        let loaded = load_params(&dir).unwrap();
        assert_eq!(params, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }
}
