//! Minimal TOML-subset parser: `[section]` headers and `key = value`
//! lines where value is a quoted string, integer, float, or bool.
//! Comments (`#`) and blank lines are ignored. No arrays/tables-of-tables
//! — the config schema doesn't need them.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    sections: HashMap<String, HashMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {:?}", lineno + 1, line);
            };
            let value = parse_value(value.trim())
                .ok_or_else(|| anyhow::anyhow!("line {}: bad value {:?}", lineno + 1, value))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string is preserved
    let mut in_string = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_string = !in_string,
            '#' if !in_string => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str) -> Option<TomlValue> {
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        return Some(TomlValue::Str(inner.to_string()));
    }
    match raw {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_types() {
        let doc = TomlDoc::parse(
            "[a]\ns = \"hi\"\ni = 42\nf = 2.5\nb = true\nneg = -7\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("a", "s"), Some("hi"));
        assert_eq!(doc.get_int("a", "i"), Some(42));
        assert_eq!(doc.get_float("a", "f"), Some(2.5));
        assert_eq!(doc.get_float("a", "i"), Some(42.0)); // int coerces
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_int("a", "neg"), Some(-7));
    }

    #[test]
    fn comments_and_blanks() {
        let doc = TomlDoc::parse("# top\n[s] # trailing\nk = 1 # note\n\nq = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_int("s", "k"), Some(1));
        assert_eq!(doc.get_str("s", "q"), Some("a#b"));
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("[s]\nk = 1\n").unwrap();
        assert_eq!(doc.get_int("s", "missing"), None);
        assert_eq!(doc.get_int("missing", "k"), None);
        assert_eq!(doc.get_str("s", "k"), None); // wrong type
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("[s]\nnot a kv\n").is_err());
        assert!(TomlDoc::parse("[s]\nk = @@\n").is_err());
    }

    #[test]
    fn keyless_sections_ok() {
        let doc = TomlDoc::parse("[empty]\n").unwrap();
        assert_eq!(doc.get_int("empty", "x"), None);
    }
}
