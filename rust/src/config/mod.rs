//! Typed configuration + a minimal TOML-subset parser (sections,
//! `key = value` with strings / ints / floats / bools — no serde in the
//! offline image) and presets matching the paper's two evaluation setups.

mod toml;

pub use toml::{TomlDoc, TomlValue};

use crate::device::faults::{FaultModel, ScrubConfig};
use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::search::cascade::{CascadeConfig, CascadeStage, Shortlist};
use crate::search::routing::{Probes, RefreshPolicy, RoutingConfig};
use crate::search::SearchMode;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The `[cascade]` TOML section: a progressive-precision prune-and-refine
/// schedule in its canonical two-stage form — a coarse column-prefix pass
/// over every slot, then a full-precision refine of the shortlist
/// (DESIGN.md §Cascade). Resolved against the engine's word length by
/// [`CascadeSettings::to_cascade`]; richer multi-stage schedules are
/// available programmatically via [`CascadeConfig`].
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeSettings {
    /// Coarse-stage column prefix; `None` = half the code word (≥ 1).
    pub coarse_columns: Option<usize>,
    /// Coarse-stage SA ladder depth; `None` = the engine's full ladder.
    pub coarse_ladder: Option<usize>,
    /// Shortlist carried into the refine stage, as a count
    /// (ignored when [`Self::shortlist_fraction`] is set).
    pub shortlist: usize,
    /// Shortlist as a keep-fraction of the live slots, `0 < f <= 1`.
    pub shortlist_fraction: Option<f64>,
    /// Early-exit margin (stage vote units); infinite = never exit.
    pub safety_margin: f64,
    /// Per-request word-line iteration budget.
    pub iteration_budget: Option<u64>,
}

impl Default for CascadeSettings {
    fn default() -> Self {
        CascadeSettings {
            coarse_columns: None,
            coarse_ladder: None,
            shortlist: 64,
            shortlist_fraction: None,
            safety_margin: f64::INFINITY,
            iteration_budget: None,
        }
    }
}

impl CascadeSettings {
    /// Resolve into an engine schedule for a `word_length`-column code
    /// word (the engine re-validates against its own layout).
    pub fn to_cascade(&self, word_length: usize) -> CascadeConfig {
        let columns = self.coarse_columns.unwrap_or_else(|| (word_length / 2).max(1));
        let shortlist = match self.shortlist_fraction {
            Some(f) => Shortlist::Fraction(f),
            None => Shortlist::Count(self.shortlist),
        };
        let mut stage0 = CascadeStage::coarse(columns, shortlist);
        if let Some(ladder) = self.coarse_ladder {
            stage0 = stage0.with_ladder_len(ladder);
        }
        let mut cascade = CascadeConfig::new(vec![stage0, CascadeStage::full()])
            .with_safety_margin(self.safety_margin);
        if let Some(budget) = self.iteration_budget {
            cascade = cascade.with_iteration_budget(budget);
        }
        cascade
    }

    pub fn validate(&self) -> Result<()> {
        if self.shortlist == 0 {
            bail!("cascade shortlist must be >= 1");
        }
        if let Some(f) = self.shortlist_fraction {
            if !f.is_finite() || f <= 0.0 || f > 1.0 {
                bail!("cascade shortlist_fraction must be in (0, 1]");
            }
        }
        if self.coarse_columns == Some(0) {
            bail!("cascade coarse_columns must be >= 1");
        }
        if self.coarse_ladder == Some(0) {
            bail!("cascade coarse_ladder must be >= 1");
        }
        if self.safety_margin.is_nan() || self.safety_margin < 0.0 {
            bail!("cascade safety_margin must be >= 0");
        }
        if self.iteration_budget == Some(0) {
            bail!("cascade iteration_budget must be >= 1");
        }
        Ok(())
    }
}

/// The `[routing]` TOML section: the hierarchical shard-routing tier
/// (DESIGN.md §Routing). Enabled with `enabled = true`; the defaults
/// probe the best 4 shards per query with lazy centroid refresh, so
/// `[routing]\nenabled = true` alone turns flat sharding into a routed
/// fleet. Resolved by [`RoutingSettings::to_routing`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingSettings {
    /// Shards probed per query, as a count (ignored when
    /// [`Self::fraction`] is set). `None` with no fraction resolves to
    /// [`Probes::All`] — the exact bypass.
    pub probes: Option<usize>,
    /// Shards probed per query, as a fraction of the eligible shards
    /// (`0 < f <= 1`); takes precedence over [`Self::probes`].
    pub fraction: Option<f64>,
    /// Minimum fraction of live slots the probed shards must cover
    /// (the probe set widens best-first until it does).
    pub min_coverage: f64,
    /// Centroid refresh policy: `"eager"` or `"lazy"`.
    pub refresh: RefreshPolicy,
}

impl Default for RoutingSettings {
    fn default() -> Self {
        RoutingSettings {
            probes: Some(4),
            fraction: None,
            min_coverage: 0.0,
            refresh: RefreshPolicy::Lazy,
        }
    }
}

impl RoutingSettings {
    /// Resolve into the engine's routing policy (the engine re-validates
    /// at install time).
    pub fn to_routing(&self) -> RoutingConfig {
        let probes = match (self.fraction, self.probes) {
            (Some(f), _) => Probes::Fraction(f),
            (None, Some(n)) => Probes::Count(n),
            (None, None) => Probes::All,
        };
        RoutingConfig { probes, refresh: self.refresh, min_coverage: self.min_coverage }
    }

    pub fn validate(&self) -> Result<()> {
        self.to_routing().validate()?;
        Ok(())
    }
}

/// The `[faults]` TOML section: persistent device-fault statistics
/// installed on every engine replica (DESIGN.md §Reliability). Enabled
/// with `enabled = true`; the rates default to the worn-device profile
/// ([`FaultModel::worn`]) so `[faults]\nenabled = true` alone simulates
/// end-of-life flash.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSettings {
    /// Per-cell probability of being stuck at the lowest level.
    pub stuck_low: f64,
    /// Per-cell probability of being stuck at the highest level.
    pub stuck_high: f64,
    /// Per-cell per-age-tick retention drift probability.
    pub retention_drift: f64,
    /// Per-cell per-sense read-disturb probability.
    pub read_disturb: f64,
}

impl Default for FaultSettings {
    fn default() -> Self {
        let worn = FaultModel::worn();
        FaultSettings {
            stuck_low: worn.stuck_low,
            stuck_high: worn.stuck_high,
            retention_drift: worn.retention_drift,
            read_disturb: worn.read_disturb,
        }
    }
}

impl FaultSettings {
    pub fn to_model(&self) -> FaultModel {
        FaultModel {
            stuck_low: self.stuck_low,
            stuck_high: self.stuck_high,
            retention_drift: self.retention_drift,
            read_disturb: self.read_disturb,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.to_model().validate()?;
        Ok(())
    }
}

/// The `[scrub]` TOML section: online scrub policy + cadence
/// (DESIGN.md §Reliability). `enabled = true` installs a
/// [`ScrubConfig`] on every replica and schedules a background pass on
/// each worker every [`Self::every_batches`] served batches.
#[derive(Debug, Clone, PartialEq)]
pub struct ScrubSettings {
    /// Known-pattern canary strings per shard.
    pub canaries: usize,
    /// Spare slots per shard for remapping persistently-stuck strings.
    pub spares: usize,
    /// Canary cell-match fraction below which a shard reports `Degraded`.
    pub margin_threshold: f64,
    /// Worker-side cadence: scrub after this many served batches.
    pub every_batches: u64,
}

impl Default for ScrubSettings {
    fn default() -> Self {
        let scrub = ScrubConfig::default();
        ScrubSettings {
            canaries: scrub.canaries,
            spares: scrub.spares,
            margin_threshold: scrub.margin_threshold,
            every_batches: 32,
        }
    }
}

impl ScrubSettings {
    pub fn to_scrub(&self) -> ScrubConfig {
        ScrubConfig {
            canaries: self.canaries,
            spares: self.spares,
            margin_threshold: self.margin_threshold,
            ..ScrubConfig::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.to_scrub().validate()?;
        if self.every_batches == 0 {
            bail!("scrub every_batches must be >= 1");
        }
        Ok(())
    }
}

/// The `[serve]` TOML section: network limits for `mcamvss serve
/// --listen` (the TCP front end of
/// [`crate::coordinator::network::NetServer`]). Distinct from `[server]`,
/// which sizes the in-process coordinator (workers, queues, batching).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSettings {
    /// Address to listen on (e.g. `"127.0.0.1:7171"`); `None` keeps
    /// `serve` in its in-process closed-loop mode unless `--listen` is
    /// passed.
    pub listen: Option<String>,
    /// Maximum simultaneously-live client connections.
    pub max_connections: usize,
    /// Per-connection cap on unanswered requests.
    pub max_in_flight: usize,
    /// Close a quiet connection after this long (milliseconds).
    pub idle_timeout_ms: u64,
    /// Refuse wire frames whose declared body exceeds this many bytes.
    pub max_frame_bytes: usize,
    /// On shutdown, wait at most this long (milliseconds) per
    /// connection for in-flight responses.
    pub drain_timeout_ms: u64,
}

impl Default for ServeSettings {
    fn default() -> Self {
        ServeSettings {
            listen: None,
            max_connections: 64,
            max_in_flight: 32,
            idle_timeout_ms: 30_000,
            max_frame_bytes: 4 << 20,
            drain_timeout_ms: 5_000,
        }
    }
}

impl ServeSettings {
    /// Resolve into the network layer's config struct.
    pub fn to_net_config(&self) -> crate::coordinator::network::NetConfig {
        crate::coordinator::network::NetConfig {
            max_connections: self.max_connections,
            max_in_flight: self.max_in_flight,
            idle_timeout: std::time::Duration::from_millis(self.idle_timeout_ms),
            max_frame_bytes: self.max_frame_bytes,
            drain_timeout: std::time::Duration::from_millis(self.drain_timeout_ms),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_connections == 0 {
            bail!("serve max_connections must be >= 1");
        }
        if self.max_in_flight == 0 {
            bail!("serve max_in_flight must be >= 1");
        }
        if self.idle_timeout_ms == 0 || self.idle_timeout_ms > 3_600_000 {
            bail!("serve idle_timeout_ms must be in 1..=3600000");
        }
        if self.drain_timeout_ms > 3_600_000 {
            bail!("serve drain_timeout_ms must be <= 3600000");
        }
        if self.max_frame_bytes < 64 {
            bail!("serve max_frame_bytes must be >= 64 (one frame header + a tiny body)");
        }
        Ok(())
    }
}

/// The `[snapshot]` TOML section: zero-downtime support refresh for
/// `serve --listen`. When [`Self::watch`] names an artifact directory,
/// the serve loop polls its `manifest.txt` and, on change, loads a new
/// support set and hot-swaps every worker replica via
/// [`crate::coordinator::Server::install_snapshot`] — in-flight
/// requests keep being answered by the old version until their batch
/// boundary (DESIGN.md §Snapshots).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotSettings {
    /// Artifact directory to watch for refreshed support embeddings;
    /// `None` disables the refresh loop.
    pub watch: Option<String>,
    /// How often the serve loop checks the watch directory (milliseconds).
    pub poll_ms: u64,
}

impl Default for SnapshotSettings {
    fn default() -> Self {
        SnapshotSettings { watch: None, poll_ms: 500 }
    }
}

impl SnapshotSettings {
    pub fn validate(&self) -> Result<()> {
        if self.poll_ms == 0 || self.poll_ms > 3_600_000 {
            bail!("snapshot poll_ms must be in 1..=3600000");
        }
        if let Some(watch) = &self.watch {
            if watch.is_empty() {
                bail!("snapshot watch path must be non-empty");
            }
        }
        Ok(())
    }
}

/// Budgeted hyper-parameters for one HAT training run (mirror of the
/// python `TrainSettings` in `compile/hat.py`), consumed by
/// [`crate::hat`]. Presets follow the python module; `synth` targets
/// the rust-native dataset of `hat::data`.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSettings {
    pub pretrain_steps: usize,
    pub pretrain_bs: usize,
    pub meta_episodes: usize,
    pub n_way: usize,
    pub k_shot: usize,
    pub n_query: usize,
    /// Support code word length trained against (support levels 3cl+1).
    pub hat_cl: usize,
    pub lr: f64,
    pub meta_lr: f64,
    /// Lognormal device-noise sigma injected by the simulated MCAM.
    pub noise_sigma: f64,
}

impl TrainSettings {
    /// Omniglot budget (python `OMNIGLOT_TRAIN`).
    pub fn omniglot() -> TrainSettings {
        TrainSettings {
            pretrain_steps: 600,
            pretrain_bs: 64,
            meta_episodes: 120,
            n_way: 20,
            k_shot: 5,
            n_query: 5,
            hat_cl: 8,
            lr: 1e-3,
            meta_lr: 2e-4,
            noise_sigma: 0.15,
        }
    }

    /// CUB budget (python `CUB_TRAIN`).
    pub fn cub() -> TrainSettings {
        TrainSettings {
            pretrain_steps: 400,
            pretrain_bs: 64,
            meta_episodes: 80,
            n_way: 10,
            k_shot: 5,
            n_query: 4,
            hat_cl: 8,
            lr: 1e-3,
            meta_lr: 2e-4,
            noise_sigma: 0.15,
        }
    }

    /// Rust-native synthetic dataset budget (the `train` CLI default).
    pub fn synth() -> TrainSettings {
        TrainSettings {
            pretrain_steps: 80,
            pretrain_bs: 16,
            meta_episodes: 24,
            n_way: 4,
            k_shot: 2,
            n_query: 2,
            hat_cl: 4,
            lr: 1e-3,
            meta_lr: 2e-4,
            noise_sigma: 0.15,
        }
    }

    /// Shrink to CI-smoke scale (keeps every stage >= 2 steps so loss
    /// traces remain meaningful).
    pub fn smoke(mut self) -> TrainSettings {
        self.pretrain_steps = self.pretrain_steps.min(40);
        self.meta_episodes = self.meta_episodes.min(2);
        self.n_way = self.n_way.min(4);
        self.k_shot = self.k_shot.min(2);
        self.n_query = self.n_query.min(2);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.pretrain_steps == 0 || self.pretrain_bs == 0 || self.meta_episodes == 0 {
            bail!("training budget must be positive");
        }
        if self.n_way == 0 || self.k_shot == 0 || self.n_query == 0 {
            bail!("training episode shape must be positive");
        }
        if self.hat_cl == 0 {
            bail!("hat_cl must be >= 1");
        }
        if self.noise_sigma < 0.0 {
            bail!("noise_sigma must be >= 0");
        }
        Ok(())
    }
}

/// Full system configuration for the `mcamvss` binary.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub dataset: String,
    pub variant: String,
    pub encoding: Encoding,
    pub cl: usize,
    pub mode: SearchMode,
    pub n_way: usize,
    pub k_shot: usize,
    pub n_query: usize,
    pub episodes: usize,
    pub workers: usize,
    pub queue_capacity: usize,
    pub max_batch: usize,
    /// MCAM blocks the support set is sharded across (per engine replica).
    pub shards: usize,
    pub ladder_len: usize,
    pub variation: VariationModel,
    pub seed: u64,
    /// HAT training budget for the `train` subcommand.
    pub train: TrainSettings,
    /// Network limits for `serve --listen` (`[serve]` section).
    pub serve: ServeSettings,
    /// Zero-downtime support refresh for `serve --listen`
    /// (`[snapshot]` section / `--snapshot-watch` flag).
    pub snapshot: SnapshotSettings,
    /// Optional progressive-precision cascade (`[cascade]` section /
    /// `--cascade` flags); `None` serves full scans.
    pub cascade: Option<CascadeSettings>,
    /// Optional hierarchical shard routing (`[routing]` section /
    /// `--routing` flags); `None` senses every shard on every request.
    pub routing: Option<RoutingSettings>,
    /// Optional persistent device faults (`[faults]` section /
    /// `--faults` flag); `None` serves a pristine device.
    pub faults: Option<FaultSettings>,
    /// Optional online scrub policy + worker cadence (`[scrub]` section /
    /// `--scrub` flag).
    pub scrub: Option<ScrubSettings>,
}

impl Config {
    /// Paper setup: Omniglot, 200-way 10-shot, MTMC CL=32, AVSS, HAT.
    pub fn omniglot_preset() -> Config {
        Config {
            dataset: "omniglot".into(),
            variant: "hat_avss".into(),
            encoding: Encoding::Mtmc,
            cl: 32,
            mode: SearchMode::Avss,
            n_way: 200,
            k_shot: 10,
            n_query: 5,
            episodes: 10,
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            shards: 1,
            ladder_len: 16,
            variation: VariationModel::nand_default(),
            seed: 0x5EED,
            train: TrainSettings::omniglot(),
            serve: ServeSettings::default(),
            snapshot: SnapshotSettings::default(),
            cascade: None,
            routing: None,
            faults: None,
            scrub: None,
        }
    }

    /// Paper setup: CUB, 50-way 5-shot, MTMC CL=25, AVSS, HAT.
    pub fn cub_preset() -> Config {
        Config {
            dataset: "cub".into(),
            variant: "hat_avss".into(),
            encoding: Encoding::Mtmc,
            cl: 25,
            mode: SearchMode::Avss,
            n_way: 50,
            k_shot: 5,
            n_query: 5,
            episodes: 10,
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            shards: 1,
            ladder_len: 16,
            variation: VariationModel::nand_default(),
            seed: 0x5EED,
            train: TrainSettings::cub(),
            serve: ServeSettings::default(),
            snapshot: SnapshotSettings::default(),
            cascade: None,
            routing: None,
            faults: None,
            scrub: None,
        }
    }

    /// Rust-native synthetic dataset (trained and exported by the
    /// `train` subcommand — no python sidecar in the loop).
    pub fn synth_preset() -> Config {
        Config {
            dataset: "synth".into(),
            variant: "hat_avss".into(),
            encoding: Encoding::Mtmc,
            cl: 4,
            mode: SearchMode::Avss,
            n_way: 4,
            k_shot: 2,
            n_query: 2,
            episodes: 10,
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            shards: 1,
            ladder_len: 16,
            variation: VariationModel::nand_default(),
            seed: 0x5EED,
            train: TrainSettings::synth(),
            serve: ServeSettings::default(),
            snapshot: SnapshotSettings::default(),
            cascade: None,
            routing: None,
            faults: None,
            scrub: None,
        }
    }

    pub fn preset(name: &str) -> Result<Config> {
        match name {
            "omniglot" => Ok(Self::omniglot_preset()),
            "cub" => Ok(Self::cub_preset()),
            "synth" => Ok(Self::synth_preset()),
            other => bail!("unknown preset {other:?} (omniglot | cub | synth)"),
        }
    }

    /// Parse a TOML-subset config file, starting from the preset named in
    /// `[system] dataset` and overriding fields present in the file.
    pub fn from_toml(doc: &TomlDoc) -> Result<Config> {
        let dataset = doc
            .get_str("system", "dataset")
            .unwrap_or("omniglot")
            .to_string();
        let mut cfg = Config::preset(&dataset)?;
        if let Some(v) = doc.get_str("system", "variant") {
            cfg.variant = v.to_string();
        }
        if let Some(e) = doc.get_str("search", "encoding") {
            cfg.encoding =
                Encoding::from_name(e).with_context(|| format!("bad encoding {e:?}"))?;
        }
        if let Some(cl) = doc.get_int("search", "cl") {
            cfg.cl = cl as usize;
        }
        if let Some(m) = doc.get_str("search", "mode") {
            cfg.mode = SearchMode::from_name(m).with_context(|| format!("bad mode {m:?}"))?;
        }
        if let Some(n) = doc.get_int("episode", "n_way") {
            cfg.n_way = n as usize;
        }
        if let Some(k) = doc.get_int("episode", "k_shot") {
            cfg.k_shot = k as usize;
        }
        if let Some(q) = doc.get_int("episode", "n_query") {
            cfg.n_query = q as usize;
        }
        if let Some(e) = doc.get_int("episode", "episodes") {
            cfg.episodes = e as usize;
        }
        if let Some(w) = doc.get_int("server", "workers") {
            cfg.workers = w as usize;
        }
        if let Some(c) = doc.get_int("server", "queue_capacity") {
            cfg.queue_capacity = c as usize;
        }
        if let Some(b) = doc.get_int("server", "max_batch") {
            cfg.max_batch = b as usize;
        }
        if let Some(s) = doc.get_int("server", "shards") {
            cfg.shards = s as usize;
        }
        if let Some(l) = doc.get_int("device", "ladder_len") {
            cfg.ladder_len = l as usize;
        }
        if let Some(p) = doc.get_float("device", "program_sigma") {
            cfg.variation.program_sigma = p;
        }
        if let Some(r) = doc.get_float("device", "read_sigma") {
            cfg.variation.read_sigma = r;
        }
        if let Some(s) = doc.get_int("system", "seed") {
            cfg.seed = s as u64;
        }
        if let Some(v) = doc.get_int("train", "pretrain_steps") {
            cfg.train.pretrain_steps = v as usize;
        }
        if let Some(v) = doc.get_int("train", "pretrain_bs") {
            cfg.train.pretrain_bs = v as usize;
        }
        if let Some(v) = doc.get_int("train", "meta_episodes") {
            cfg.train.meta_episodes = v as usize;
        }
        if let Some(v) = doc.get_int("train", "n_way") {
            cfg.train.n_way = v as usize;
        }
        if let Some(v) = doc.get_int("train", "k_shot") {
            cfg.train.k_shot = v as usize;
        }
        if let Some(v) = doc.get_int("train", "n_query") {
            cfg.train.n_query = v as usize;
        }
        if let Some(v) = doc.get_int("train", "hat_cl") {
            cfg.train.hat_cl = v as usize;
        }
        if let Some(v) = doc.get_float("train", "lr") {
            cfg.train.lr = v;
        }
        if let Some(v) = doc.get_float("train", "meta_lr") {
            cfg.train.meta_lr = v;
        }
        if let Some(v) = doc.get_float("train", "noise_sigma") {
            cfg.train.noise_sigma = v;
        }
        if let Some(addr) = doc.get_str("serve", "listen") {
            cfg.serve.listen = Some(addr.to_string());
        }
        {
            // Sign-checked integer reads for the [serve] section.
            let get_pos = |key: &str| -> Result<Option<usize>> {
                match doc.get_int("serve", key) {
                    None => Ok(None),
                    Some(v) if v >= 1 => Ok(Some(v as usize)),
                    Some(v) => bail!("serve {key} must be >= 1, got {v}"),
                }
            };
            if let Some(v) = get_pos("max_connections")? {
                cfg.serve.max_connections = v;
            }
            if let Some(v) = get_pos("max_in_flight")? {
                cfg.serve.max_in_flight = v;
            }
            if let Some(v) = get_pos("idle_timeout_ms")? {
                cfg.serve.idle_timeout_ms = v as u64;
            }
            if let Some(v) = get_pos("max_frame_bytes")? {
                cfg.serve.max_frame_bytes = v;
            }
            if let Some(v) = get_pos("drain_timeout_ms")? {
                cfg.serve.drain_timeout_ms = v as u64;
            }
        }
        if doc.get_bool("cascade", "enabled") == Some(true) {
            // Sign-checked integer reads: a negative value must be a
            // config error, not a silent `as usize` wrap into a huge
            // (and then silently clamped) count.
            let get_pos = |key: &str| -> Result<Option<usize>> {
                match doc.get_int("cascade", key) {
                    None => Ok(None),
                    Some(v) if v >= 1 => Ok(Some(v as usize)),
                    Some(v) => bail!("cascade {key} must be >= 1, got {v}"),
                }
            };
            let mut cascade = CascadeSettings::default();
            if let Some(v) = get_pos("coarse_columns")? {
                cascade.coarse_columns = Some(v);
            }
            if let Some(v) = get_pos("coarse_ladder")? {
                cascade.coarse_ladder = Some(v);
            }
            if let Some(v) = get_pos("shortlist")? {
                cascade.shortlist = v;
            }
            if let Some(v) = doc.get_float("cascade", "shortlist_fraction") {
                cascade.shortlist_fraction = Some(v);
            }
            if let Some(v) = doc.get_float("cascade", "safety_margin") {
                cascade.safety_margin = v;
            }
            if let Some(v) = get_pos("iteration_budget")? {
                cascade.iteration_budget = Some(v as u64);
            }
            cfg.cascade = Some(cascade);
        }
        if doc.get_bool("routing", "enabled") == Some(true) {
            let get_pos = |key: &str| -> Result<Option<usize>> {
                match doc.get_int("routing", key) {
                    None => Ok(None),
                    Some(v) if v >= 1 => Ok(Some(v as usize)),
                    Some(v) => bail!("routing {key} must be >= 1, got {v}"),
                }
            };
            let mut routing = RoutingSettings::default();
            if let Some(v) = get_pos("probes")? {
                routing.probes = Some(v);
            }
            if let Some(v) = doc.get_float("routing", "fraction") {
                routing.fraction = Some(v);
            }
            if let Some(v) = doc.get_float("routing", "min_coverage") {
                routing.min_coverage = v;
            }
            if let Some(v) = doc.get_str("routing", "refresh") {
                routing.refresh = match v.to_ascii_lowercase().as_str() {
                    "eager" => RefreshPolicy::Eager,
                    "lazy" => RefreshPolicy::Lazy,
                    other => bail!("routing refresh must be \"eager\" or \"lazy\", got {other:?}"),
                };
            }
            cfg.routing = Some(routing);
        }
        if doc.get_bool("faults", "enabled") == Some(true) {
            // Rates default to the worn-device profile; each key
            // overrides one rate. Range checks live in
            // FaultModel::validate (reached via cfg.validate()).
            let mut faults = FaultSettings::default();
            if let Some(v) = doc.get_float("faults", "stuck_low") {
                faults.stuck_low = v;
            }
            if let Some(v) = doc.get_float("faults", "stuck_high") {
                faults.stuck_high = v;
            }
            if let Some(v) = doc.get_float("faults", "retention_drift") {
                faults.retention_drift = v;
            }
            if let Some(v) = doc.get_float("faults", "read_disturb") {
                faults.read_disturb = v;
            }
            cfg.faults = Some(faults);
        }
        if doc.get_bool("scrub", "enabled") == Some(true) {
            let get_pos = |key: &str| -> Result<Option<usize>> {
                match doc.get_int("scrub", key) {
                    None => Ok(None),
                    Some(v) if v >= 1 => Ok(Some(v as usize)),
                    Some(v) => bail!("scrub {key} must be >= 1, got {v}"),
                }
            };
            let mut scrub = ScrubSettings::default();
            if let Some(v) = get_pos("canaries")? {
                scrub.canaries = v;
            }
            if let Some(v) = get_pos("spares")? {
                scrub.spares = v;
            }
            if let Some(v) = doc.get_float("scrub", "margin_threshold") {
                scrub.margin_threshold = v;
            }
            if let Some(v) = get_pos("every_batches")? {
                scrub.every_batches = v as u64;
            }
            cfg.scrub = Some(scrub);
        }
        if let Some(watch) = doc.get_str("snapshot", "watch") {
            cfg.snapshot.watch = Some(watch.to_string());
        }
        match doc.get_int("snapshot", "poll_ms") {
            None => {}
            Some(v) if v >= 1 => cfg.snapshot.poll_ms = v as u64,
            Some(v) => bail!("snapshot poll_ms must be >= 1, got {v}"),
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_toml(&TomlDoc::parse(&text)?)
    }

    pub fn validate(&self) -> Result<()> {
        if self.cl == 0 {
            bail!("cl must be >= 1");
        }
        if self.n_way == 0 || self.k_shot == 0 || self.n_query == 0 {
            bail!("episode shape must be positive");
        }
        if self.workers == 0 {
            bail!("need at least one worker");
        }
        if self.shards == 0 {
            bail!("need at least one MCAM shard");
        }
        if self.encoding == Encoding::B4e && self.cl > 9 {
            bail!("B4E beyond CL=9 overflows 4^CL levels (paper sweeps 1..9)");
        }
        self.train.validate()?;
        self.serve.validate()?;
        self.snapshot.validate()?;
        if let Some(cascade) = &self.cascade {
            cascade.validate()?;
        }
        if let Some(routing) = &self.routing {
            routing.validate()?;
        }
        if let Some(faults) = &self.faults {
            faults.validate()?;
        }
        if let Some(scrub) = &self.scrub {
            scrub.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        Config::omniglot_preset().validate().unwrap();
        Config::cub_preset().validate().unwrap();
        assert!(Config::preset("nope").is_err());
    }

    #[test]
    fn toml_overrides() {
        let doc = TomlDoc::parse(
            r#"
[system]
dataset = "cub"
variant = "std"
[search]
encoding = "b4e"
cl = 3
mode = "svss"
[episode]
n_way = 10
[server]
workers = 4
shards = 2
[device]
program_sigma = 0.3
"#,
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        assert_eq!(cfg.dataset, "cub");
        assert_eq!(cfg.variant, "std");
        assert_eq!(cfg.encoding, Encoding::B4e);
        assert_eq!(cfg.cl, 3);
        assert_eq!(cfg.mode, SearchMode::Svss);
        assert_eq!(cfg.n_way, 10);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.shards, 2);
        assert_eq!(cfg.variation.program_sigma, 0.3);
        // untouched fields keep the preset
        assert_eq!(cfg.k_shot, 5);
    }

    #[test]
    fn rejects_bad_values() {
        let doc = TomlDoc::parse("[search]\nencoding = \"huffman\"\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[search]\nencoding = \"b4e\"\ncl = 20\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[train]\nhat_cl = 0\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
    }

    #[test]
    fn cascade_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[cascade]\nenabled = true\ncoarse_columns = 2\ncoarse_ladder = 4\n\
             shortlist = 32\nsafety_margin = 6.5\niteration_budget = 40\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        let cascade = cfg.cascade.expect("enabled section");
        assert_eq!(cascade.coarse_columns, Some(2));
        assert_eq!(cascade.coarse_ladder, Some(4));
        assert_eq!(cascade.shortlist, 32);
        assert_eq!(cascade.safety_margin, 6.5);
        assert_eq!(cascade.iteration_budget, Some(40));
        let resolved = cascade.to_cascade(8);
        assert_eq!(resolved.stages.len(), 2);
        resolved.validate().unwrap();

        // not enabled → no cascade
        let doc = TomlDoc::parse("[cascade]\nshortlist = 32\n").unwrap();
        assert!(Config::from_toml(&doc).unwrap().cascade.is_none());

        // malformed values are rejected
        let doc = TomlDoc::parse("[cascade]\nenabled = true\nshortlist = 0\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        // negative integers must error, never wrap through `as usize`
        let doc = TomlDoc::parse("[cascade]\nenabled = true\nshortlist = -4\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[cascade]\nenabled = true\ncoarse_columns = -1\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        let doc =
            TomlDoc::parse("[cascade]\nenabled = true\nshortlist_fraction = 1.5\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[cascade]\nenabled = true\niteration_budget = 0\n").unwrap();
        assert!(Config::from_toml(&doc).is_err());
    }

    #[test]
    fn cascade_settings_resolve_defaults() {
        let settings = CascadeSettings::default();
        settings.validate().unwrap();
        let cascade = settings.to_cascade(8);
        assert_eq!(cascade.stages[0].columns, Some(4), "half the word by default");
        assert_eq!(cascade.stages[0].ladder_len, None);
        assert!(cascade.safety_margin.is_infinite());
        // fraction takes precedence over the count
        let settings = CascadeSettings {
            shortlist_fraction: Some(0.25),
            ..CascadeSettings::default()
        };
        let cascade = settings.to_cascade(1);
        assert_eq!(cascade.stages[0].columns, Some(1), "floor of one column");
        assert!(matches!(
            cascade.stages[0].shortlist,
            crate::search::cascade::Shortlist::Fraction(f) if f == 0.25
        ));
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[serve]\nlisten = \"127.0.0.1:7171\"\nmax_connections = 8\n\
             max_in_flight = 4\nidle_timeout_ms = 1000\nmax_frame_bytes = 65536\n\
             drain_timeout_ms = 250\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        assert_eq!(cfg.serve.listen.as_deref(), Some("127.0.0.1:7171"));
        assert_eq!(cfg.serve.max_connections, 8);
        assert_eq!(cfg.serve.max_in_flight, 4);
        assert_eq!(cfg.serve.idle_timeout_ms, 1000);
        assert_eq!(cfg.serve.max_frame_bytes, 65536);
        assert_eq!(cfg.serve.drain_timeout_ms, 250);
        let net = cfg.serve.to_net_config();
        assert_eq!(net.max_connections, 8);
        assert_eq!(net.idle_timeout, std::time::Duration::from_millis(1000));

        // defaults apply without the section
        let cfg = Config::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.serve, ServeSettings::default());
        assert!(cfg.serve.listen.is_none());

        // zero / negative / absurd values are typed config errors
        for bad in [
            "[serve]\nmax_connections = 0\n",
            "[serve]\nmax_in_flight = -2\n",
            "[serve]\nidle_timeout_ms = 9999999999\n",
            "[serve]\nmax_frame_bytes = 8\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(Config::from_toml(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn snapshot_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[snapshot]\nwatch = \"/tmp/mcamvss_snap\"\npoll_ms = 100\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        assert_eq!(cfg.snapshot.watch.as_deref(), Some("/tmp/mcamvss_snap"));
        assert_eq!(cfg.snapshot.poll_ms, 100);

        // defaults apply without the section: refresh loop disabled
        let cfg = Config::from_toml(&TomlDoc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.snapshot, SnapshotSettings::default());
        assert!(cfg.snapshot.watch.is_none());

        // zero / negative / absurd cadences are typed config errors
        for bad in [
            "[snapshot]\npoll_ms = 0\n",
            "[snapshot]\npoll_ms = -5\n",
            "[snapshot]\npoll_ms = 9999999999\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(Config::from_toml(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn routing_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[routing]\nenabled = true\nprobes = 2\nmin_coverage = 0.5\nrefresh = \"eager\"\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        let routing = cfg.routing.expect("enabled section");
        assert_eq!(routing.probes, Some(2));
        assert_eq!(routing.min_coverage, 0.5);
        assert_eq!(routing.refresh, RefreshPolicy::Eager);
        let resolved = routing.to_routing();
        assert_eq!(resolved.probes, Probes::Count(2));
        assert_eq!(resolved.min_coverage, 0.5);
        resolved.validate().unwrap();

        // fraction takes precedence over the count
        let doc =
            TomlDoc::parse("[routing]\nenabled = true\nprobes = 2\nfraction = 0.25\n").unwrap();
        let routing = Config::from_toml(&doc).unwrap().routing.unwrap();
        assert_eq!(routing.to_routing().probes, Probes::Fraction(0.25));

        // a bare enable is the probe-4 lazy default
        let doc = TomlDoc::parse("[routing]\nenabled = true\n").unwrap();
        let routing = Config::from_toml(&doc).unwrap().routing.unwrap();
        assert_eq!(routing, RoutingSettings::default());
        assert_eq!(routing.to_routing().probes, Probes::Count(4));

        // not enabled → None
        let doc = TomlDoc::parse("[routing]\nprobes = 2\n").unwrap();
        assert!(Config::from_toml(&doc).unwrap().routing.is_none());

        // malformed values are typed config errors
        for bad in [
            "[routing]\nenabled = true\nprobes = 0\n",
            "[routing]\nenabled = true\nprobes = -2\n",
            "[routing]\nenabled = true\nfraction = 1.5\n",
            "[routing]\nenabled = true\nfraction = 0.0\n",
            "[routing]\nenabled = true\nmin_coverage = -0.5\n",
            "[routing]\nenabled = true\nmin_coverage = 1.5\n",
            "[routing]\nenabled = true\nrefresh = \"sometimes\"\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(Config::from_toml(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn faults_and_scrub_sections_parse_and_validate() {
        let doc = TomlDoc::parse(
            "[faults]\nenabled = true\nstuck_low = 0.01\nread_disturb = 0.001\n\
             [scrub]\nenabled = true\ncanaries = 8\nspares = 3\n\
             margin_threshold = 0.8\nevery_batches = 16\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        let faults = cfg.faults.expect("enabled section");
        assert_eq!(faults.stuck_low, 0.01);
        assert_eq!(faults.read_disturb, 0.001);
        // unset rates keep the worn-device profile
        assert_eq!(faults.stuck_high, FaultModel::worn().stuck_high);
        assert_eq!(faults.retention_drift, FaultModel::worn().retention_drift);
        faults.to_model().validate().unwrap();
        let scrub = cfg.scrub.expect("enabled section");
        assert_eq!(scrub.canaries, 8);
        assert_eq!(scrub.spares, 3);
        assert_eq!(scrub.margin_threshold, 0.8);
        assert_eq!(scrub.every_batches, 16);
        scrub.to_scrub().validate().unwrap();

        // not enabled → None; a bare enable is the worn-device default
        let cfg = Config::from_toml(&TomlDoc::parse("[faults]\nstuck_low = 0.5\n").unwrap())
            .unwrap();
        assert!(cfg.faults.is_none() && cfg.scrub.is_none());
        let cfg = Config::from_toml(
            &TomlDoc::parse("[faults]\nenabled = true\n[scrub]\nenabled = true\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.faults, Some(FaultSettings::default()));
        assert_eq!(cfg.scrub, Some(ScrubSettings::default()));

        // out-of-range rates and zero/negative counts are config errors
        for bad in [
            "[faults]\nenabled = true\nstuck_low = 1.5\n",
            "[faults]\nenabled = true\nretention_drift = -0.1\n",
            "[faults]\nenabled = true\nstuck_low = 0.6\nstuck_high = 0.6\n",
            "[scrub]\nenabled = true\ncanaries = 0\n",
            "[scrub]\nenabled = true\nspares = -1\n",
            "[scrub]\nenabled = true\nmargin_threshold = 1.5\n",
            "[scrub]\nenabled = true\nevery_batches = 0\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(Config::from_toml(&doc).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn train_presets_validate_and_override() {
        TrainSettings::omniglot().validate().unwrap();
        TrainSettings::cub().validate().unwrap();
        TrainSettings::synth().validate().unwrap();
        let smoke = TrainSettings::omniglot().smoke();
        assert!(smoke.meta_episodes <= 2 && smoke.pretrain_steps <= 40);
        smoke.validate().unwrap();

        let doc = TomlDoc::parse(
            "[train]\npretrain_steps = 7\nmeta_episodes = 3\nhat_cl = 2\nnoise_sigma = 0.05\n\
             n_way = 8\nk_shot = 1\nn_query = 3\n",
        )
        .unwrap();
        let cfg = Config::from_toml(&doc).unwrap();
        assert_eq!(cfg.train.pretrain_steps, 7);
        assert_eq!(cfg.train.meta_episodes, 3);
        assert_eq!(cfg.train.hat_cl, 2);
        assert_eq!(cfg.train.noise_sigma, 0.05);
        assert_eq!((cfg.train.n_way, cfg.train.k_shot, cfg.train.n_query), (8, 1, 3));
        // untouched training fields keep the preset
        assert_eq!(cfg.train.pretrain_bs, TrainSettings::omniglot().pretrain_bs);
    }
}
