//! Vector → NAND-string layout (§2.3 / Fig. 4 of the paper, generalised to
//! the group-column layout shared with `python/compile/mcam_sim.py`).
//!
//! A support vector with `d` dimensions and physical word length `W`
//! (= `encoding.word_length(cl)`) is padded to `G = ceil(d / 24)` groups
//! of 24 dimensions and stored in `G × W` adjacent strings:
//!
//! ```text
//! string (g, c), cell l   ←   code word c of dimension 24 g + l
//! ```
//!
//! Because every string of column *c* in group *g* exposes the *same*
//! dimensions at the same word lines, one word-line application can sense:
//!
//! * **SVSS** — column *c* of group *g* only (the query's word *c* drives
//!   the lines): `G × W` iterations per search;
//! * **AVSS** — *all W columns* of group *g* at once (the query's single
//!   4-level word drives the lines): `G` iterations per search — the
//!   paper's ⌈d/24⌉, a `W×` reduction.
//!
//! The engine programs support strings **column-major** within a shard
//! (all vectors' string (g, c) adjacent), and the block stores cells
//! **cell-major** (one plane per word line, strings contiguous within a
//! plane — [`crate::device::block::McamBlock`]): together, every search
//! iteration streams contiguous plane segments through the fused sense
//! kernel instead of gathering string-major rows (DESIGN.md §Perf).

pub mod capacity;

use crate::encoding::Encoding;
use crate::CELLS_PER_STRING;

/// Layout of one encoded vector across MCAM strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorLayout {
    /// Logical embedding dimensions.
    pub dims: usize,
    /// Code word length parameter (base digits for B4WE).
    pub cl: usize,
    /// Physical code words per dimension.
    pub word_length: usize,
    /// Dimension groups of 24.
    pub groups: usize,
}

impl VectorLayout {
    pub fn new(dims: usize, encoding: Encoding, cl: usize) -> VectorLayout {
        assert!(dims >= 1, "need at least one dimension");
        let word_length = encoding.word_length(cl);
        let groups = dims.div_ceil(CELLS_PER_STRING);
        VectorLayout { dims, cl, word_length, groups }
    }

    /// Strings occupied per support vector.
    pub fn strings_per_vector(&self) -> usize {
        self.groups * self.word_length
    }

    /// SVSS search iterations per query (⌈d/24⌉ × W ≈ ⌈CL·d/24⌉).
    pub fn svss_iterations(&self) -> usize {
        self.groups * self.word_length
    }

    /// AVSS search iterations per query (⌈d/24⌉).
    pub fn avss_iterations(&self) -> usize {
        self.groups
    }

    /// String index (within the vector's group of strings) of (g, c).
    pub fn string_index(&self, group: usize, column: usize) -> usize {
        debug_assert!(group < self.groups && column < self.word_length);
        group * self.word_length + column
    }

    /// Scatter a dimension-major encoded vector (`dims × word_length`
    /// words, as produced by [`Encoding::encode_vector`]) into per-string
    /// cell arrays. Padding dimensions hold level 0.
    pub fn strings_for(&self, words: &[u8]) -> Vec<[u8; CELLS_PER_STRING]> {
        assert_eq!(
            words.len(),
            self.dims * self.word_length,
            "encoded vector has wrong word count"
        );
        let mut strings =
            vec![[0u8; CELLS_PER_STRING]; self.strings_per_vector()];
        for dim in 0..self.dims {
            let group = dim / CELLS_PER_STRING;
            let lane = dim % CELLS_PER_STRING;
            for column in 0..self.word_length {
                strings[self.string_index(group, column)][lane] =
                    words[dim * self.word_length + column];
            }
        }
        strings
    }

    /// Build the word-line drive for SVSS iteration (g, c) from the
    /// query's encoded words (dimension-major, same shape as support).
    pub fn svss_wordline(
        &self,
        query_words: &[u8],
        group: usize,
        column: usize,
    ) -> [u8; CELLS_PER_STRING] {
        assert_eq!(query_words.len(), self.dims * self.word_length);
        let mut wl = [0u8; CELLS_PER_STRING];
        for lane in 0..CELLS_PER_STRING {
            let dim = group * CELLS_PER_STRING + lane;
            if dim < self.dims {
                wl[lane] = query_words[dim * self.word_length + column];
            }
        }
        wl
    }

    /// Build the word-line drive for AVSS iteration g from the query's
    /// single 4-level word per dimension.
    pub fn avss_wordline(&self, query_q4: &[u8], group: usize) -> [u8; CELLS_PER_STRING] {
        assert_eq!(query_q4.len(), self.dims, "AVSS query needs one word per dim");
        let mut wl = [0u8; CELLS_PER_STRING];
        for lane in 0..CELLS_PER_STRING {
            let dim = group * CELLS_PER_STRING + lane;
            if dim < self.dims {
                wl[lane] = query_q4[dim];
            }
        }
        wl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn paper_iteration_counts() {
        // Omniglot: d=48, MTMC CL=32 → SVSS 64, AVSS 2 (32× reduction).
        let omni = VectorLayout::new(48, Encoding::Mtmc, 32);
        assert_eq!(omni.svss_iterations(), 64);
        assert_eq!(omni.avss_iterations(), 2);
        // CUB: d=480, MTMC CL=25 → SVSS 500, AVSS 20 (25× reduction).
        let cub = VectorLayout::new(480, Encoding::Mtmc, 25);
        assert_eq!(cub.svss_iterations(), 500);
        assert_eq!(cub.avss_iterations(), 20);
    }

    #[test]
    fn strings_per_vector_matches_paper_formula() {
        // ⌈d×CL/24⌉ for 24 | d — Fig. 4's k.
        let l = VectorLayout::new(48, Encoding::Mtmc, 2);
        assert_eq!(l.strings_per_vector(), 4); // 48*2/24
    }

    #[test]
    fn scatter_places_words() {
        let dims = 48;
        let cl = 2;
        let l = VectorLayout::new(dims, Encoding::Mtmc, cl);
        // distinct values per dim so we can trace placement
        let values: Vec<u32> = (0..dims as u32).map(|d| d % 7).collect();
        let words = Encoding::Mtmc.encode_vector(&values, cl);
        let strings = l.strings_for(&words);
        assert_eq!(strings.len(), 4);
        for dim in 0..dims {
            let (g, lane) = (dim / 24, dim % 24);
            for c in 0..cl {
                assert_eq!(
                    strings[l.string_index(g, c)][lane],
                    words[dim * cl + c],
                    "dim {dim} col {c}"
                );
            }
        }
    }

    #[test]
    fn padding_lanes_are_zero() {
        let l = VectorLayout::new(30, Encoding::Mtmc, 3); // 2 groups, 6 pad lanes
        let values = vec![9u32; 30];
        let strings = l.strings_for(&Encoding::Mtmc.encode_vector(&values, 3));
        for c in 0..3 {
            let s = strings[l.string_index(1, c)];
            for lane in 6..24 {
                assert_eq!(s[lane], 0, "pad lane {lane}");
            }
        }
    }

    #[test]
    fn svss_wordline_selects_column() {
        let l = VectorLayout::new(48, Encoding::B4e, 3);
        let values: Vec<u32> = (0..48).map(|d| (d * 7 % 64) as u32).collect();
        let words = Encoding::B4e.encode_vector(&values, 3);
        for g in 0..2 {
            for c in 0..3 {
                let wl = l.svss_wordline(&words, g, c);
                for lane in 0..24 {
                    assert_eq!(wl[lane], words[(g * 24 + lane) * 3 + c]);
                }
            }
        }
    }

    #[test]
    fn avss_wordline_uses_q4() {
        let l = VectorLayout::new(30, Encoding::Mtmc, 4);
        let q4: Vec<u8> = (0..30).map(|d| (d % 4) as u8).collect();
        let wl = l.avss_wordline(&q4, 1);
        for lane in 0..6 {
            assert_eq!(wl[lane], q4[24 + lane]);
        }
        for lane in 6..24 {
            assert_eq!(wl[lane], 0);
        }
    }

    #[test]
    fn match_consistency_svss() {
        // Programming a vector then driving its own SVSS word lines must
        // produce zero mismatch in every string — for any encoding.
        forall(
            "self-match has zero mismatch",
            48,
            |rng| {
                let enc = crate::encoding::ALL_ENCODINGS[rng.below(4)];
                let cl = 1 + rng.below(3);
                let dims = 1 + rng.below(60);
                let values: Vec<u32> =
                    (0..dims).map(|_| rng.below(enc.levels(cl)) as u32).collect();
                (enc, cl, dims, values)
            },
            |&(enc, cl, dims, ref values)| {
                let l = VectorLayout::new(dims, enc, cl);
                let words = enc.encode_vector(values, cl);
                let strings = l.strings_for(&words);
                for g in 0..l.groups {
                    for c in 0..l.word_length {
                        let wl = l.svss_wordline(&words, g, c);
                        let s = strings[l.string_index(g, c)];
                        if wl != s {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }
}
