//! Multi-block capacity planning.
//!
//! A single MCAM block holds 128K strings; the paper's Omniglot setting
//! (2000 support vectors × 64 strings) fills one block exactly, and any
//! larger support set (more ways, more shots, longer code words) must
//! shard across blocks. The planner assigns whole support vectors to
//! blocks (a vector's strings must share word lines, so vectors never
//! straddle a block) and reports the search-iteration consequences:
//! blocks search in parallel, so iterations stay per-block while energy
//! scales with the total sensed strings.

use super::VectorLayout;
use crate::STRINGS_PER_BLOCK;

/// A sharding plan for `n_vectors` support vectors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CapacityPlan {
    pub n_vectors: usize,
    pub strings_per_vector: usize,
    pub vectors_per_block: usize,
    pub blocks: usize,
    /// Vector index ranges per block (`[start, end)`).
    pub shards: Vec<(usize, usize)>,
}

/// Plan the block sharding for a support set under `layout`.
/// `block_strings` is the per-block capacity (the real device's 128K).
pub fn plan(layout: &VectorLayout, n_vectors: usize, block_strings: usize) -> CapacityPlan {
    let spv = layout.strings_per_vector();
    assert!(
        spv <= block_strings,
        "one vector needs {spv} strings > block capacity {block_strings}"
    );
    let vectors_per_block = block_strings / spv;
    let blocks = n_vectors.div_ceil(vectors_per_block).max(1);
    let mut shards = Vec::with_capacity(blocks);
    let mut start = 0;
    while start < n_vectors {
        let end = (start + vectors_per_block).min(n_vectors);
        shards.push((start, end));
        start = end;
    }
    if shards.is_empty() {
        shards.push((0, 0));
    }
    CapacityPlan {
        n_vectors,
        strings_per_vector: spv,
        vectors_per_block,
        blocks: shards.len(),
        shards,
    }
}

/// Plan against the paper's 128K-string block.
pub fn plan_default(layout: &VectorLayout, n_vectors: usize) -> CapacityPlan {
    plan(layout, n_vectors, STRINGS_PER_BLOCK)
}

impl CapacityPlan {
    /// Total strings occupied across all blocks.
    pub fn total_strings(&self) -> usize {
        self.n_vectors * self.strings_per_vector
    }

    /// Occupancy of the fullest block (0..=1).
    pub fn peak_utilization(&self, block_strings: usize) -> f64 {
        self.shards
            .iter()
            .map(|&(s, e)| (e - s) * self.strings_per_vector)
            .fold(0, usize::max) as f64
            / block_strings as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Encoding;

    #[test]
    fn paper_omniglot_setting_fills_one_block() {
        // §4.1: 200-way 10-shot at CL=32 needs "up to 128K NAND strings".
        let layout = VectorLayout::new(48, Encoding::Mtmc, 32);
        let plan = plan_default(&layout, 2000);
        assert_eq!(plan.total_strings(), 128_000);
        assert_eq!(plan.blocks, 1);
        assert!(plan.peak_utilization(STRINGS_PER_BLOCK) > 0.97);
    }

    #[test]
    fn paper_cub_setting_fits_one_block() {
        // §4.1: 50-way 5-shot at CL=25 occupies "up to 125K strings".
        let layout = VectorLayout::new(480, Encoding::Mtmc, 25);
        let plan = plan_default(&layout, 250);
        assert_eq!(plan.total_strings(), 125_000);
        assert_eq!(plan.blocks, 1);
    }

    #[test]
    fn overflow_shards_across_blocks() {
        let layout = VectorLayout::new(48, Encoding::Mtmc, 32); // 64 spv
        let plan = plan_default(&layout, 5000); // 320K strings
        assert_eq!(plan.blocks, 3);
        assert_eq!(plan.shards[0], (0, 2048));
        assert_eq!(plan.shards[2].1, 5000);
        // every vector assigned exactly once
        let covered: usize = plan.shards.iter().map(|&(s, e)| e - s).sum();
        assert_eq!(covered, 5000);
    }

    #[test]
    fn small_blocks() {
        let layout = VectorLayout::new(24, Encoding::Mtmc, 2); // 2 spv
        let plan = plan(&layout, 7, 6); // 3 vectors/block
        assert_eq!(plan.vectors_per_block, 3);
        assert_eq!(plan.blocks, 3);
        assert_eq!(plan.shards, vec![(0, 3), (3, 6), (6, 7)]);
    }

    #[test]
    #[should_panic(expected = "block capacity")]
    fn vector_larger_than_block_panics() {
        let layout = VectorLayout::new(480, Encoding::Mtmc, 25); // 500 spv
        plan(&layout, 1, 100);
    }

    #[test]
    fn empty_support() {
        let layout = VectorLayout::new(48, Encoding::Mtmc, 2);
        let plan = plan_default(&layout, 0);
        assert_eq!(plan.blocks, 1);
        assert_eq!(plan.total_strings(), 0);
    }
}
