//! Figs. 3 and 5: mismatch-level analyses of B4E (Fig. 3) vs MTMC
//! (Fig. 5).
//!
//! (a) fraction of code-word positions at each mismatch level over
//!     query/support pairs from the test embeddings, split target
//!     (same class) vs non-target, across code word lengths;
//! (b) probability of each *max* mismatch level as a function of value
//!     distance, over all value pairs of a 64-level grid (B4E CL=3,
//!     MTMC CL=21 → 64 levels).

use crate::encoding::analysis::{
    max_mismatch_by_distance, mismatch_type_distribution, MaxMismatchRow,
    MismatchHistogram,
};
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::fsl::EmbeddingDataset;
use crate::quant::QuantSpec;
use crate::testutil::Rng;
use anyhow::Result;

/// One (a)-panel row: mismatch-type distribution at a code word length.
#[derive(Debug, Clone)]
pub struct DistributionRow {
    pub encoding: Encoding,
    pub cl: usize,
    pub target: MismatchHistogram,
    pub non_target: MismatchHistogram,
}

/// Sample (query, support) embedding-dimension value pairs from episodes
/// of the dataset, split into target / non-target.
fn sample_value_pairs(
    ds: &EmbeddingDataset,
    clip: f64,
    levels: usize,
    pairs_per_kind: usize,
    rng: &mut Rng,
) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
    let spec = QuantSpec::new(levels, clip);
    let classes = ds.classes();
    let mut target = Vec::with_capacity(pairs_per_kind);
    let mut non_target = Vec::with_capacity(pairs_per_kind);
    while target.len() < pairs_per_kind {
        let class = classes[rng.below(classes.len())];
        let rows = ds.class_rows(class);
        if rows.len() < 2 {
            continue;
        }
        let picks = rng.choose_distinct(rows.len(), 2);
        let a = ds.embedding(rows[picks[0]]);
        let b = ds.embedding(rows[picks[1]]);
        let d = rng.below(ds.dims);
        target.push((spec.quantize(a[d] as f64), spec.quantize(b[d] as f64)));
    }
    while non_target.len() < pairs_per_kind {
        let ci = rng.choose_distinct(classes.len(), 2);
        let ra = ds.class_rows(classes[ci[0]]);
        let rb = ds.class_rows(classes[ci[1]]);
        let a = ds.embedding(ra[rng.below(ra.len())]);
        let b = ds.embedding(rb[rng.below(rb.len())]);
        let d = rng.below(ds.dims);
        non_target.push((spec.quantize(a[d] as f64), spec.quantize(b[d] as f64)));
    }
    (target, non_target)
}

/// Panel (a) for one encoding across code word lengths, on real test
/// embeddings of (dataset, variant).
pub fn panel_a(
    store: &ArtifactStore,
    dataset: &str,
    variant: &str,
    encoding: Encoding,
    cls: &[usize],
    pairs_per_kind: usize,
    seed: u64,
) -> Result<Vec<DistributionRow>> {
    let ds = store.embeddings(dataset, variant, "test")?;
    let clip = store.clip(dataset, variant)?;
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();
    for &cl in cls {
        let levels = encoding.levels(cl);
        let (target, non_target) =
            sample_value_pairs(&ds, clip, levels, pairs_per_kind, &mut rng);
        rows.push(DistributionRow {
            encoding,
            cl,
            target: mismatch_type_distribution(encoding, cl, &target),
            non_target: mismatch_type_distribution(encoding, cl, &non_target),
        });
    }
    Ok(rows)
}

/// Panel (b): max-mismatch probability vs distance at 64 levels.
pub fn panel_b(encoding: Encoding) -> Vec<MaxMismatchRow> {
    let cl = match encoding {
        Encoding::B4e => 3,   // 4^3 = 64 levels
        Encoding::Mtmc => 21, // 3*21+1 = 64 levels
        Encoding::B4we => 3,
        Encoding::Sre => 1,
    };
    max_mismatch_by_distance(encoding, cl)
}

pub fn render_panel_a(rows: &[DistributionRow]) -> String {
    let mut out = String::from("encoding  cl  kind        m0      m1      m2      m3\n");
    for row in rows {
        for (kind, hist) in [("target", &row.target), ("nontarget", &row.non_target)] {
            let f = hist.fractions();
            out.push_str(&format!(
                "{:>8} {:>3}  {:<9} {:.4}  {:.4}  {:.4}  {:.4}\n",
                row.encoding.name(),
                row.cl,
                kind,
                f[0],
                f[1],
                f[2],
                f[3]
            ));
        }
    }
    out
}

pub fn render_panel_b(encoding: Encoding) -> String {
    let rows = panel_b(encoding);
    let mut out = format!(
        "{}: max-mismatch probability vs value distance (64 levels)\n",
        encoding.name()
    );
    out.push_str("distance  P(max=0)  P(max=1)  P(max=2)  P(max=3)\n");
    for row in rows.iter().step_by(4) {
        out.push_str(&format!(
            "{:>8}  {:.4}    {:.4}    {:.4}    {:.4}\n",
            row.distance, row.prob[0], row.prob[1], row.prob[2], row.prob[3]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_b_shapes_match_paper() {
        // Fig. 3(b): B4E has mismatch-3 mass at small distances.
        let b4e = panel_b(Encoding::B4e);
        assert!(b4e[1].prob[3] > 0.0, "B4E distance-1 pairs can hit mismatch-3");
        // Fig. 5(b): MTMC has zero mismatch>=2 mass below distance CL=21.
        let mtmc = panel_b(Encoding::Mtmc);
        for row in mtmc.iter().take(21) {
            assert_eq!(row.prob[2] + row.prob[3], 0.0, "distance {}", row.distance);
        }
        // and the max mismatch grows (weakly) with distance
        assert!(mtmc[63].prob[3] > 0.9);
    }

    #[test]
    fn render_panel_b_has_rows() {
        let text = render_panel_b(Encoding::Mtmc);
        assert!(text.lines().count() > 10);
    }

    // panel_a is artifact-dependent; covered by rust/tests + bench.
}
