//! `fig_faults` — the reliability campaign (DESIGN.md §Reliability; this
//! figure has no paper counterpart — it measures the fault/scrub story
//! §2.3 motivates but never quantifies).
//!
//! A deliberately *hard* synthetic episode (64 tightly packed classes,
//! 48-d, protos drawn close together so device damage actually moves
//! decisions) is programmed into an otherwise-ideal engine. Each sweep
//! point runs the same protocol:
//!
//! 1. **clean** — a fresh engine with no faults, the accuracy ceiling;
//! 2. **faulty** — a fresh engine with a [`FaultModel`] installed and the
//!    retention clock advanced; its accuracy is the *no-scrub* outcome
//!    (the scrub-off arm of the scrub axis);
//! 3. **scrubbed** — the same damaged engine after one
//!    [`SearchEngine::scrub`] pass (canary re-sense, reprogram drifted
//!    slots, remap persistently-stuck slots to spares).
//!
//! `recovered_frac` is the fraction of the fault-induced accuracy loss
//! the scrub pass won back. Retention drift heals completely (the epoch
//! bump redraws thresholds at zero age); stuck damage only heals up to
//! the spare budget, so the stuck-heavy rows honestly report partial
//! recovery and `Degraded` shards (served majority-of-3).
//!
//! Axes: fault scenario (stuck-at rate, retention age, read disturb,
//! the `worn()` end-of-life profile) × encoding (MTMC / B4E / SRE) ×
//! controller (HAT vs non-HAT, trained on the rust-native synth set).

use crate::device::faults::{FaultModel, ScrubConfig};
use crate::encoding::Encoding;
use crate::hat;
use crate::metrics::CsvTable;
use crate::search::engine::{EngineConfig, SearchEngine};
use crate::search::{SearchMode, SearchRequest, ShardHealth};
use crate::testutil::Rng;
use anyhow::Result;

/// Episode shape: same scale as `fig_cascade` (512 slots, 64-way) but
/// with the classes packed close together — protos jittered around a
/// common center instead of spanning the quantizer range — so the clean
/// margin is thin enough for §2.3-scale faults to cost accuracy.
const DIMS: usize = 48;
const CLASSES: usize = 64;
const PER_CLASS: usize = 8;
const QUERIES_PER_CLASS: usize = 4;
const CL: usize = 8;
const CLIP: f64 = 3.0;
const PROTO_CENTER: f64 = 1.6;
const PROTO_SPREAD: f64 = 0.12;
const JITTER: f64 = 0.05;

/// Logical retention age the `worn()` acceptance point is measured at:
/// `1 − 0.98^80 ≈ 0.80` of cells past their drift threshold.
const WORN_AGE: u64 = 80;

/// One measured reliability point.
#[derive(Debug, Clone)]
pub struct FaultPoint {
    pub label: String,
    pub encoding: String,
    /// Controller axis: `true` for hardware-aware-trained embeddings.
    pub hat: bool,
    pub faults: FaultModel,
    /// Logical retention age at measurement time.
    pub age: u64,
    /// Accuracy ceiling (fresh engine, no faults).
    pub clean_accuracy_pct: f64,
    /// Accuracy with faults installed and no scrub — the no-scrub arm.
    pub faulty_accuracy_pct: f64,
    /// Accuracy after one scrub pass on the damaged engine.
    pub scrubbed_accuracy_pct: f64,
    /// Fraction of the fault-induced loss the scrub won back (1.0 when
    /// nothing was lost).
    pub recovered_frac: f64,
    pub strings_scrubbed: u64,
    pub slots_reprogrammed: u64,
    pub slots_remapped: u64,
    pub spares_remaining: usize,
    pub canary_margin: f64,
    /// Shards left `Degraded` after the scrub (spares exhausted / thin
    /// margin) — these serve majority-of-3.
    pub degraded_after_scrub: usize,
}

/// The full campaign.
#[derive(Debug, Clone)]
pub struct FaultSweep {
    pub points: Vec<FaultPoint>,
}

impl FaultSweep {
    /// The MTMC full-precision `worn()` acceptance point.
    pub fn worn_mtmc(&self) -> Option<&FaultPoint> {
        self.points
            .iter()
            .find(|p| !p.hat && p.encoding == "mtmc" && p.faults == FaultModel::worn())
    }
}

/// Deterministic hard episode: tightly packed class protos, members and
/// queries jittered around them.
fn synth_episode(seed: u64) -> (Vec<Vec<f32>>, Vec<u32>, Vec<Vec<f32>>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let mut support = Vec::with_capacity(CLASSES * PER_CLASS);
    let mut labels = Vec::with_capacity(CLASSES * PER_CLASS);
    let mut queries = Vec::with_capacity(CLASSES * QUERIES_PER_CLASS);
    let mut truth = Vec::with_capacity(CLASSES * QUERIES_PER_CLASS);
    for c in 0..CLASSES {
        let proto: Vec<f64> =
            (0..DIMS).map(|_| PROTO_CENTER + PROTO_SPREAD * rng.gaussian()).collect();
        for _ in 0..PER_CLASS {
            support.push(jitter(&proto, &mut rng));
            labels.push(c as u32);
        }
        for _ in 0..QUERIES_PER_CLASS {
            queries.push(jitter(&proto, &mut rng));
            truth.push(c as u32);
        }
    }
    (support, labels, queries, truth)
}

fn jitter(proto: &[f64], rng: &mut Rng) -> Vec<f32> {
    proto.iter().map(|&p| (p + JITTER * rng.gaussian()).max(0.0) as f32).collect()
}

/// Top-1 accuracy of `engine` over the query set.
fn accuracy_pct(
    engine: &mut SearchEngine,
    queries: &[Vec<f32>],
    truth: &[u32],
) -> Result<f64> {
    let mut correct = 0usize;
    for (query, &want) in queries.iter().zip(truth) {
        let response = engine.search(&SearchRequest::new(query))?;
        if response.top().map(|h| h.label) == Some(want) {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / truth.len() as f64)
}

fn fresh_engine(
    encoding: Encoding,
    cl: usize,
    clip: f64,
    dims: usize,
    refs: &[&[f32]],
    labels: &[u32],
    seed: u64,
) -> Result<SearchEngine> {
    let cfg = EngineConfig::new(encoding, cl, SearchMode::Avss, clip).ideal().with_seed(seed);
    let mut engine = SearchEngine::new(cfg, dims, refs.len())?;
    engine.program_support(refs, labels)?;
    Ok(engine)
}

/// Run the clean / faulty / scrubbed protocol for one configuration.
#[allow(clippy::too_many_arguments)]
fn measure_point(
    label: &str,
    encoding: Encoding,
    hat: bool,
    cl: usize,
    clip: f64,
    dims: usize,
    refs: &[&[f32]],
    labels: &[u32],
    queries: &[Vec<f32>],
    truth: &[u32],
    faults: FaultModel,
    age: u64,
    seed: u64,
) -> Result<FaultPoint> {
    // clean ceiling: a separate engine, so its sense counts never feed
    // the damaged engine's read-disturb accumulation
    let mut clean = fresh_engine(encoding, cl, clip, dims, refs, labels, seed)?;
    let clean_accuracy_pct = accuracy_pct(&mut clean, queries, truth)?;

    // faulty: same seed (bitwise-identical programming), faults on,
    // retention clock advanced, no scrub — the no-scrub arm
    let mut engine = fresh_engine(encoding, cl, clip, dims, refs, labels, seed)?;
    engine.set_faults(faults)?;
    engine.advance_age(age);
    let faulty_accuracy_pct = accuracy_pct(&mut engine, queries, truth)?;

    // scrubbed: one pass over the same damaged engine, then re-measure
    engine.set_scrub(Some(ScrubConfig::default()))?;
    let report = engine.scrub()?;
    let scrubbed_accuracy_pct = accuracy_pct(&mut engine, queries, truth)?;

    let lost = clean_accuracy_pct - faulty_accuracy_pct;
    let recovered_frac = if lost > 1e-9 {
        (scrubbed_accuracy_pct - faulty_accuracy_pct) / lost
    } else {
        1.0
    };
    let degraded_after_scrub =
        engine.shard_health().iter().filter(|h| **h == ShardHealth::Degraded).count();
    Ok(FaultPoint {
        label: label.to_string(),
        encoding: encoding.name().to_string(),
        hat,
        faults,
        age,
        clean_accuracy_pct,
        faulty_accuracy_pct,
        scrubbed_accuracy_pct,
        recovered_frac,
        strings_scrubbed: report.strings_scrubbed,
        slots_reprogrammed: report.slots_reprogrammed,
        slots_remapped: report.slots_remapped,
        spares_remaining: report.spares_remaining,
        canary_margin: report.canary_margin,
        degraded_after_scrub,
    })
}

/// The device-axis scenarios (label, rates, retention age). `worn()` at
/// [`WORN_AGE`] is the acceptance point.
fn scenarios() -> Vec<(&'static str, FaultModel, u64)> {
    vec![
        ("no faults", FaultModel::NONE, 0),
        (
            "stuck 1%",
            FaultModel { stuck_low: 0.005, stuck_high: 0.005, ..FaultModel::NONE },
            0,
        ),
        ("drift age 20", FaultModel { retention_drift: 0.02, ..FaultModel::NONE }, 20),
        (
            "disturb",
            FaultModel { read_disturb: 5e-5, ..FaultModel::NONE },
            0,
        ),
        ("worn age 80", FaultModel::worn(), WORN_AGE),
    ]
}

/// Device sweep: every scenario at MTMC, plus the worn acceptance
/// scenario across the alternative encodings.
fn device_points(seed: u64) -> Result<Vec<FaultPoint>> {
    let (support, labels, queries, truth) = synth_episode(seed);
    let refs: Vec<&[f32]> = support.iter().map(|e| e.as_slice()).collect();
    let mut points = Vec::new();
    for (label, faults, age) in scenarios() {
        points.push(measure_point(
            label,
            Encoding::Mtmc,
            false,
            CL,
            CLIP,
            DIMS,
            &refs,
            &labels,
            &queries,
            &truth,
            faults,
            age,
            seed,
        )?);
    }
    for encoding in [Encoding::B4e, Encoding::Sre] {
        points.push(measure_point(
            "worn age 80",
            encoding,
            false,
            CL,
            CLIP,
            DIMS,
            &refs,
            &labels,
            &queries,
            &truth,
            FaultModel::worn(),
            WORN_AGE,
            seed,
        )?);
    }
    Ok(points)
}

/// Controller axis: train the rust-native synth controller twice (`std`
/// vs the paper's `hat_avss`) and measure both embedding spaces at the
/// worn acceptance scenario. Support/queries split the embedded test
/// classes `k_shot`-first.
fn hat_points(seed: u64) -> Result<Vec<FaultPoint>> {
    let synth = hat::data::generate(hat::data::SynthSpec::default_spec(), seed);
    let cfg = hat::SYNTH_CONTROLLER;
    let settings = crate::config::TrainSettings::synth();
    let (pretrained, _) = hat::pretrain(&synth.train, &cfg, &settings, seed, &mut |_| {});
    let mut points = Vec::new();
    for variant in ["std", "hat_avss"] {
        let params = hat::meta_train(
            &pretrained,
            &synth.train,
            &cfg,
            &settings,
            variant,
            seed,
            &mut |_| {},
        )?;
        let train_emb = hat::embed_all(&params, &cfg, &synth.train);
        let clip = crate::quant::calibrate_clip(&train_emb, crate::quant::CLIP_SIGMA);
        let test_emb = hat::embed_all(&params, &cfg, &synth.test);
        let dim = cfg.embed_dim;
        let row = |r: usize| &test_emb[r * dim..(r + 1) * dim];

        let mut refs: Vec<&[f32]> = Vec::new();
        let mut labels: Vec<u32> = Vec::new();
        let mut queries: Vec<Vec<f32>> = Vec::new();
        let mut truth: Vec<u32> = Vec::new();
        for class in synth.test.classes() {
            for (i, &r) in synth.test.class_rows(class).iter().enumerate() {
                if i < settings.k_shot + 2 {
                    refs.push(row(r));
                    labels.push(class);
                } else {
                    queries.push(row(r).to_vec());
                    truth.push(class);
                }
            }
        }
        let hardware_aware = variant != "std";
        points.push(measure_point(
            &format!("worn age 80 ({variant})"),
            Encoding::Mtmc,
            hardware_aware,
            settings.hat_cl,
            clip,
            dim,
            &refs,
            &labels,
            &queries,
            &truth,
            FaultModel::worn(),
            WORN_AGE,
            seed,
        )?);
    }
    Ok(points)
}

/// Run the full campaign. Deterministic for a fixed seed (ideal device;
/// every fault decision is a pure hash of the fault stream).
pub fn run(seed: u64) -> Result<FaultSweep> {
    let mut points = device_points(seed)?;
    points.extend(hat_points(seed)?);
    Ok(FaultSweep { points })
}

/// Render the campaign as a text table.
pub fn render(sweep: &FaultSweep) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "fig_faults — fault / scrub campaign ({} slots, {}-way packed synth + HAT synth)\n",
        CLASSES * PER_CLASS,
        CLASSES
    ));
    out.push_str(
        "scenario                  | enc  | hat | clean% | faulty% | scrubbed% | recovered | reprog | remap | margin | degraded\n",
    );
    for p in &sweep.points {
        out.push_str(&format!(
            "{:<25} | {:<4} | {:<3} | {:>6.2} | {:>7.2} | {:>9.2} | {:>9.2} | {:>6} | {:>5} | {:>6.3} | {:>8}\n",
            p.label,
            p.encoding,
            if p.hat { "yes" } else { "no" },
            p.clean_accuracy_pct,
            p.faulty_accuracy_pct,
            p.scrubbed_accuracy_pct,
            p.recovered_frac,
            p.slots_reprogrammed,
            p.slots_remapped,
            p.canary_margin,
            p.degraded_after_scrub,
        ));
    }
    out
}

/// Machine-readable CSV rows (mirrors [`render`]).
pub fn csv(sweep: &FaultSweep) -> CsvTable {
    let mut table = CsvTable::new(&[
        "label",
        "encoding",
        "hat",
        "stuck_low",
        "stuck_high",
        "retention_drift",
        "read_disturb",
        "age",
        "clean_accuracy_pct",
        "faulty_accuracy_pct",
        "scrubbed_accuracy_pct",
        "recovered_frac",
        "strings_scrubbed",
        "slots_reprogrammed",
        "slots_remapped",
        "spares_remaining",
        "canary_margin",
        "degraded_after_scrub",
    ]);
    for p in &sweep.points {
        table.row(&[
            p.label.clone(),
            p.encoding.clone(),
            (p.hat as u8).to_string(),
            format!("{}", p.faults.stuck_low),
            format!("{}", p.faults.stuck_high),
            format!("{}", p.faults.retention_drift),
            format!("{}", p.faults.read_disturb),
            p.age.to_string(),
            format!("{:.3}", p.clean_accuracy_pct),
            format!("{:.3}", p.faulty_accuracy_pct),
            format!("{:.3}", p.scrubbed_accuracy_pct),
            format!("{:.4}", p.recovered_frac),
            p.strings_scrubbed.to_string(),
            p.slots_reprogrammed.to_string(),
            p.slots_remapped.to_string(),
            p.spares_remaining.to_string(),
            format!("{:.4}", p.canary_margin),
            p.degraded_after_scrub.to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fig_faults acceptance criteria on the MTMC acceptance points
    /// only (the full campaign, with the encoding and HAT axes, runs
    /// through the `experiment` CLI).
    #[test]
    fn scrub_recovers_worn_losses_at_mtmc() {
        let seed = 0xFA0175;
        let (support, labels, queries, truth) = synth_episode(seed);
        let refs: Vec<&[f32]> = support.iter().map(|e| e.as_slice()).collect();

        // no-fault anchor: installing a NONE model + scrub machinery
        // must not move accuracy at all (the no-fault path consumes no
        // fault RNG, so all three measurements are the same bitwise run)
        let none = measure_point(
            "no faults",
            Encoding::Mtmc,
            false,
            CL,
            CLIP,
            DIMS,
            &refs,
            &labels,
            &queries,
            &truth,
            FaultModel::NONE,
            0,
            seed,
        )
        .unwrap();
        assert_eq!(none.clean_accuracy_pct, none.faulty_accuracy_pct);
        assert_eq!(none.clean_accuracy_pct, none.scrubbed_accuracy_pct);
        assert_eq!(none.slots_reprogrammed, 0);
        assert_eq!(none.slots_remapped, 0);
        assert_eq!(none.canary_margin, 1.0);
        assert!(none.clean_accuracy_pct > 80.0, "episode too hard: {:.2}%", none.clean_accuracy_pct);

        // worn() at MTMC full precision: the faults must cost real
        // accuracy, and one scrub pass must win at least half of it back
        let worn = measure_point(
            "worn age 80",
            Encoding::Mtmc,
            false,
            CL,
            CLIP,
            DIMS,
            &refs,
            &labels,
            &queries,
            &truth,
            FaultModel::worn(),
            WORN_AGE,
            seed,
        )
        .unwrap();
        let lost = worn.clean_accuracy_pct - worn.faulty_accuracy_pct;
        assert!(
            lost >= 1.0,
            "worn profile cost only {lost:.2} points ({:.2}% -> {:.2}%)",
            worn.clean_accuracy_pct,
            worn.faulty_accuracy_pct
        );
        let recovered = worn.scrubbed_accuracy_pct - worn.faulty_accuracy_pct;
        assert!(
            recovered >= 0.5 * lost - 1e-9,
            "scrub recovered {recovered:.2} of {lost:.2} lost points \
             (clean {:.2}% faulty {:.2}% scrubbed {:.2}%)",
            worn.clean_accuracy_pct,
            worn.faulty_accuracy_pct,
            worn.scrubbed_accuracy_pct
        );
        assert!(worn.strings_scrubbed > 0);
        assert!(worn.slots_reprogrammed > 0, "age-80 drift must force reprograms");

        // rendering (text + CSV) covers the measured points
        let sweep = FaultSweep { points: vec![none, worn] };
        assert!(sweep.worn_mtmc().is_some());
        let text = render(&sweep);
        assert!(text.contains("worn age 80"));
        assert!(text.contains("recovered"));
        let table = csv(&sweep);
        assert!(table.render().contains("scrubbed_accuracy_pct"));
    }
}
