//! Ablations over the design choices DESIGN.md calls out:
//!
//! * SA ladder depth (sensing resolution vs energy),
//! * device-variation severity (ideal → nand-default → 2× sigma),
//! * fault injection (fresh vs worn device),
//! * encoding robustness under each of the above (MTMC vs B4E — the
//!   reliability story behind Fig. 9 in isolation).

use super::{run_mcam_eval, EpisodeSettings};
use crate::device::faults::FaultModel;
use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::fsl::{episode_rng, evaluate_episode, sample_episode};
use crate::metrics::AccuracyMeter;
use crate::search::engine::{EngineConfig, SearchEngine};
use crate::search::SearchMode;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub name: String,
    pub accuracy_pct: f64,
    pub ci95_pct: f64,
}

/// SA ladder-depth sweep (MTMC cl=8, AVSS, noisy device).
pub fn ladder_depth(
    store: &ArtifactStore,
    dataset: &str,
    settings: EpisodeSettings,
) -> Result<Vec<AblationRow>> {
    let ds = store.embeddings(dataset, "std", "test")?;
    let clip = store.clip(dataset, "std")?;
    let mut rows = Vec::new();
    for depth in [2usize, 4, 8, 16, 32] {
        let mut cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, clip)
            .with_seed(settings.seed);
        cfg.ladder_len = depth;
        let mut engine = SearchEngine::new(cfg, ds.dims, settings.n_way * settings.k_shot)?;
        let mut acc = AccuracyMeter::default();
        for ep_idx in 0..settings.episodes {
            let mut rng = episode_rng(settings.seed, ep_idx as u64);
            let ep =
                sample_episode(&ds, &mut rng, settings.n_way, settings.k_shot, settings.n_query);
            let (c, t) = evaluate_episode(&mut engine, &ds, &ep)?;
            acc.push_episode(c, t);
        }
        rows.push(AblationRow {
            name: format!("ladder={depth}"),
            accuracy_pct: acc.accuracy_pct(),
            ci95_pct: acc.ci95_pct(),
        });
    }
    Ok(rows)
}

/// Device-variation severity sweep, MTMC vs B4E (reliability margin).
pub fn variation_severity(
    store: &ArtifactStore,
    dataset: &str,
    settings: EpisodeSettings,
) -> Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for (label, variation) in [
        ("ideal", VariationModel::IDEAL),
        ("nand", VariationModel::nand_default()),
        (
            "2x-sigma",
            VariationModel { program_sigma: 0.30, read_sigma: 0.10 },
        ),
    ] {
        for (enc, cl) in [(Encoding::Mtmc, 8), (Encoding::B4e, 4)] {
            let r = run_mcam_eval(
                store,
                dataset,
                "std",
                enc,
                cl,
                SearchMode::Avss,
                variation,
                settings,
            )?;
            rows.push(AblationRow {
                name: format!("{label}/{}", enc.name()),
                accuracy_pct: r.accuracy.accuracy_pct(),
                ci95_pct: r.accuracy.ci95_pct(),
            });
        }
    }
    Ok(rows)
}

/// Fault-injection sweep (fresh vs worn device), MTMC cl=8.
pub fn fault_injection(
    store: &ArtifactStore,
    dataset: &str,
    settings: EpisodeSettings,
) -> Result<Vec<AblationRow>> {
    let ds = store.embeddings(dataset, "std", "test")?;
    let clip = store.clip(dataset, "std")?;
    let mut rows = Vec::new();
    for (label, faults) in [
        ("fresh", FaultModel::NONE),
        ("worn", FaultModel::worn()),
        (
            "heavy-retention",
            FaultModel { retention_drift: 0.10, ..FaultModel::NONE },
        ),
    ] {
        let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, clip)
            .with_seed(settings.seed);
        let mut engine = SearchEngine::new(cfg, ds.dims, settings.n_way * settings.k_shot)?;
        engine.set_faults(faults)?;
        let mut acc = AccuracyMeter::default();
        for ep_idx in 0..settings.episodes {
            let mut rng = episode_rng(settings.seed, ep_idx as u64);
            let ep =
                sample_episode(&ds, &mut rng, settings.n_way, settings.k_shot, settings.n_query);
            let (c, t) = evaluate_episode(&mut engine, &ds, &ep)?;
            acc.push_episode(c, t);
        }
        rows.push(AblationRow {
            name: format!("faults={label}"),
            accuracy_pct: acc.accuracy_pct(),
            ci95_pct: acc.ci95_pct(),
        });
    }
    Ok(rows)
}

pub fn render(title: &str, rows: &[AblationRow]) -> String {
    let mut out = format!("Ablation: {title}\n");
    for row in rows {
        out.push_str(&format!(
            "  {:<16} {:>6.2}% ±{:.2}\n",
            row.name, row.accuracy_pct, row.ci95_pct
        ));
    }
    out
}
