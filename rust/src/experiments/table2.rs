//! Table 2: accuracy and throughput of SVSS vs AVSS with HAT, at the
//! paper's full-precision settings (Omniglot MTMC CL=32, CUB CL=25).

use super::{run_mcam_eval, EpisodeSettings, RunResult};
use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::search::SearchMode;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Table2Cell {
    pub dataset: String,
    pub mode: SearchMode,
    pub result: RunResult,
}

pub fn paper_cl(dataset: &str) -> usize {
    if dataset == "cub" {
        25
    } else {
        32
    }
}

pub fn run(store: &ArtifactStore, dataset: &str, settings: EpisodeSettings) -> Result<Vec<Table2Cell>> {
    let cl = paper_cl(dataset);
    let variation = VariationModel::nand_default();
    let mut cells = Vec::new();
    for (mode, variant) in [
        (SearchMode::Svss, "hat_svss"),
        (SearchMode::Avss, "hat_avss"),
    ] {
        let result = run_mcam_eval(
            store,
            dataset,
            variant,
            Encoding::Mtmc,
            cl,
            mode,
            variation,
            settings,
        )?;
        cells.push(Table2Cell { dataset: dataset.to_string(), mode, result });
    }
    Ok(cells)
}

pub fn render(cells: &[Table2Cell]) -> String {
    let mut out = String::from(
        "Table 2: SVSS vs AVSS with HAT\n\
         dataset   mode  accuracy%        iterations  throughput(search/s)\n",
    );
    for c in cells {
        out.push_str(&format!(
            "{:<9} {:<5} {:<16} {:>10}  {:>12.1}\n",
            c.dataset,
            c.mode.name(),
            super::pct(&c.result.accuracy),
            c.result.iterations_per_search,
            c.result.throughput_per_s,
        ));
    }
    if cells.len() == 2 {
        let speedup = cells[1].result.throughput_per_s / cells[0].result.throughput_per_s;
        let drop = cells[0].result.accuracy.accuracy_pct()
            - cells[1].result.accuracy.accuracy_pct();
        out.push_str(&format!(
            "AVSS speedup {speedup:.0}x, accuracy delta {drop:+.2}% (paper: 32x/25x, -0.96%/-0.65%)\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cls() {
        assert_eq!(paper_cl("omniglot"), 32);
        assert_eq!(paper_cl("cub"), 25);
    }
}
