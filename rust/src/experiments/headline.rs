//! §1 headline claims, derived from the Table 2 and Fig. 9 machinery:
//!
//! * search-iteration reduction: 32× (Omniglot) and 25× (CUB) — pure
//!   layout arithmetic, reproduced exactly;
//! * overall accuracy improvement of the integrated framework
//!   (MTMC+HAT+AVSS) over the prior-work encodings (SRE/B4E/B4WE):
//!   paper reports +1.58%..+6.94%.

use crate::encoding::Encoding;
use crate::mapping::VectorLayout;

#[derive(Debug, Clone, Copy)]
pub struct IterationClaim {
    pub dataset: &'static str,
    pub dims: usize,
    pub cl: usize,
    pub svss_iterations: usize,
    pub avss_iterations: usize,
    pub reduction: usize,
}

/// The 32×/25× iteration-reduction claims (exact arithmetic).
pub fn iteration_claims() -> [IterationClaim; 2] {
    let make = |dataset, dims, cl| {
        let layout = VectorLayout::new(dims, Encoding::Mtmc, cl);
        IterationClaim {
            dataset,
            dims,
            cl,
            svss_iterations: layout.svss_iterations(),
            avss_iterations: layout.avss_iterations(),
            reduction: layout.svss_iterations() / layout.avss_iterations(),
        }
    };
    [make("omniglot", 48, 32), make("cub", 480, 25)]
}

pub fn render_iteration_claims() -> String {
    let mut out = String::from(
        "Headline: AVSS search-iteration reduction\n\
         dataset   d    CL  SVSS-it  AVSS-it  reduction  paper\n",
    );
    for c in iteration_claims() {
        let paper = if c.dataset == "cub" { "25x" } else { "32x" };
        out.push_str(&format!(
            "{:<9} {:>3}  {:>2}  {:>7}  {:>7}  {:>8}x  {}\n",
            c.dataset, c.dims, c.cl, c.svss_iterations, c.avss_iterations, c.reduction, paper
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reductions_match_paper_exactly() {
        let claims = iteration_claims();
        assert_eq!(claims[0].reduction, 32);
        assert_eq!(claims[0].svss_iterations, 64);
        assert_eq!(claims[0].avss_iterations, 2);
        assert_eq!(claims[1].reduction, 25);
        assert_eq!(claims[1].svss_iterations, 500);
        assert_eq!(claims[1].avss_iterations, 20);
    }

    #[test]
    fn render_mentions_both_datasets() {
        let text = render_iteration_claims();
        assert!(text.contains("omniglot") && text.contains("cub"));
    }
}
