//! Table 1: encoding rules of B4E (CL=2) vs MTMC (CL=5) for values 0–15.

use crate::encoding::Encoding;

pub struct Table1Row {
    pub value: u32,
    pub b4e: String,
    pub mtmc: String,
}

pub fn rows() -> Vec<Table1Row> {
    (0..16u32)
        .map(|value| {
            let b4e = Encoding::B4e.encode(value, 2);
            let mtmc = Encoding::Mtmc.encode(value, 5);
            Table1Row {
                value,
                // paper prints B4E most-significant digit first
                b4e: b4e.iter().rev().map(|d| d.to_string()).collect(),
                mtmc: mtmc.iter().map(|d| d.to_string()).collect(),
            }
        })
        .collect()
}

pub fn render() -> String {
    let mut out = String::from("Table 1: encoding rules (paper reproduction)\n");
    out.push_str("value  B4E  MTMC\n");
    for row in rows() {
        out.push_str(&format!("{:>5}  {:>3}  {}\n", row.value, row.b4e, row.mtmc));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_table1() {
        let expected = [
            (0, "00", "00000"),
            (1, "01", "00001"),
            (2, "02", "00011"),
            (3, "03", "00111"),
            (4, "10", "01111"),
            (5, "11", "11111"),
            (6, "12", "11112"),
            (7, "13", "11122"),
            (8, "20", "11222"),
            (9, "21", "12222"),
            (10, "22", "22222"),
            (11, "23", "22223"),
            (12, "30", "22233"),
            (13, "31", "22333"),
            (14, "32", "23333"),
            (15, "33", "33333"),
        ];
        let rows = rows();
        assert_eq!(rows.len(), 16);
        for ((value, b4e, mtmc), row) in expected.iter().zip(&rows) {
            assert_eq!(row.value, *value);
            assert_eq!(row.b4e, *b4e, "B4E value {value}");
            assert_eq!(row.mtmc, *mtmc, "MTMC value {value}");
        }
    }

    #[test]
    fn render_contains_all_rows() {
        let text = render();
        assert!(text.contains("33333"));
        assert_eq!(text.lines().count(), 18);
    }
}
