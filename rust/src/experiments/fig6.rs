//! Fig. 6: measured query–support distance under SVSS vs AVSS.
//!
//! For sampled query/support embedding pairs from the test split, compute
//! the float L1 distance (truth) and the encoded distances measured by
//! SVSS and AVSS (MTMC). The paper's panel shows AVSS's extra
//! quantization error, which asymmetric QAT then absorbs; we report the
//! mean absolute deviation from the (grid-scaled) true distance plus the
//! rank correlation, which is what prediction quality depends on.

use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::quant::QuantSpec;
use crate::search::distance::{avss_distance, l1_float, svss_distance};
use crate::testutil::Rng;
use anyhow::Result;

#[derive(Debug, Clone, Copy)]
pub struct Fig6Stats {
    pub cl: usize,
    pub pairs: usize,
    /// mean |measured - true| in support-grid units
    pub svss_mad: f64,
    pub avss_mad: f64,
    /// Spearman rank correlation with the true distance
    pub svss_rank_corr: f64,
    pub avss_rank_corr: f64,
}

fn rank(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut ranks = vec![0f64; xs.len()];
    for (r, &i) in idx.iter().enumerate() {
        ranks[i] = r as f64;
    }
    ranks
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt() + 1e-12)
}

pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&rank(a), &rank(b))
}

pub fn run(
    store: &ArtifactStore,
    dataset: &str,
    variant: &str,
    cl: usize,
    pairs: usize,
    seed: u64,
) -> Result<Fig6Stats> {
    let ds = store.embeddings(dataset, variant, "test")?;
    let clip = store.clip(dataset, variant)?;
    let spec = QuantSpec::new(Encoding::Mtmc.levels(cl), clip);
    let mut rng = Rng::new(seed);
    let mut truth = Vec::with_capacity(pairs);
    let mut svss = Vec::with_capacity(pairs);
    let mut avss = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let q = ds.embedding(rng.below(ds.len()));
        let s = ds.embedding(rng.below(ds.len()));
        truth.push(l1_float(q, s) / spec.step()); // grid units
        svss.push(svss_distance(q, s, Encoding::Mtmc, cl, clip));
        avss.push(avss_distance(q, s, Encoding::Mtmc, cl, clip));
    }
    let mad = |xs: &[f64]| -> f64 {
        xs.iter().zip(&truth).map(|(&m, &t)| (m - t).abs()).sum::<f64>() / pairs as f64
    };
    Ok(Fig6Stats {
        cl,
        pairs,
        svss_mad: mad(&svss),
        avss_mad: mad(&avss),
        svss_rank_corr: spearman(&svss, &truth),
        avss_rank_corr: spearman(&avss, &truth),
    })
}

pub fn render(stats: &Fig6Stats) -> String {
    format!(
        "Fig 6 (MTMC cl={}, {} pairs)\n\
         mode  mean|d_meas - d_true|  rank-corr(d_true)\n\
         SVSS  {:>20.3}  {:>17.4}\n\
         AVSS  {:>20.3}  {:>17.4}\n",
        stats.cl,
        stats.pairs,
        stats.svss_mad,
        stats.svss_rank_corr,
        stats.avss_mad,
        stats.avss_rank_corr,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spearman_perfect_and_inverted() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_handles_order() {
        assert_eq!(rank(&[3.0, 1.0, 2.0]), vec![2.0, 0.0, 1.0]);
    }
}
