//! Fig. 9: Pareto fronts of the energy–accuracy trade-off.
//!
//! Sweeps code word length for each encoding (SRE / B4E / B4WE / MTMC on
//! the standard controller, MTMC+HAT on the HAT controller), recording
//! per-search energy (x) and episode accuracy (y); the software
//! prototypical-network L1 baseline is the float reference line.
//! AVSS is used everywhere, matching the paper's setup.

use super::{run_mcam_eval, run_software_baseline, EpisodeSettings};
use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::search::SearchMode;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub series: String,
    pub cl: usize,
    pub nj_per_search: f64,
    pub accuracy_pct: f64,
    pub ci95_pct: f64,
}

/// Code-word-length sweeps per encoding (paper §4.2: B4WE points are the
/// base lengths giving word lengths 1/5/21; B4E sweeps 1..9; SRE/MTMC
/// sweep up to 32 for Omniglot, 25 for CUB — subsampled for runtime).
pub fn sweep_points(dataset: &str) -> Vec<(Encoding, Vec<usize>)> {
    let max_cl = if dataset == "cub" { 25 } else { 32 };
    let mut mtmc_cls = vec![1, 2, 4, 8, 16];
    if max_cl > 16 {
        mtmc_cls.push(max_cl);
    } else {
        mtmc_cls.retain(|&c| c <= max_cl);
    }
    vec![
        (Encoding::Sre, mtmc_cls.clone()),
        (Encoding::B4e, vec![1, 2, 3, 5, 7, 9]),
        (Encoding::B4we, vec![1, 2, 3]),
        (Encoding::Mtmc, mtmc_cls),
    ]
}

/// Run the full Fig. 9 sweep for one dataset.
pub fn run(
    store: &ArtifactStore,
    dataset: &str,
    settings: EpisodeSettings,
) -> Result<Vec<ParetoPoint>> {
    let variation = VariationModel::nand_default();
    let mut points = Vec::new();
    for (encoding, cls) in sweep_points(dataset) {
        for cl in cls {
            let r = run_mcam_eval(
                store,
                dataset,
                "std",
                encoding,
                cl,
                SearchMode::Avss,
                variation,
                settings,
            )?;
            points.push(ParetoPoint {
                series: encoding.name().to_string(),
                cl,
                nj_per_search: r.nj_per_search,
                accuracy_pct: r.accuracy.accuracy_pct(),
                ci95_pct: r.accuracy.ci95_pct(),
            });
        }
    }
    // MTMC + HAT series on the HAT-trained controller
    for (encoding, cls) in sweep_points(dataset) {
        if encoding != Encoding::Mtmc {
            continue;
        }
        for cl in cls {
            let r = run_mcam_eval(
                store,
                dataset,
                "hat_avss",
                encoding,
                cl,
                SearchMode::Avss,
                variation,
                settings,
            )?;
            points.push(ParetoPoint {
                series: "mtmc+hat".to_string(),
                cl,
                nj_per_search: r.nj_per_search,
                accuracy_pct: r.accuracy.accuracy_pct(),
                ci95_pct: r.accuracy.ci95_pct(),
            });
        }
    }
    // software float baseline (x = n/a, rendered separately)
    let sw = run_software_baseline(store, dataset, "std", settings)?;
    points.push(ParetoPoint {
        series: "software-l1".to_string(),
        cl: 0,
        nj_per_search: f64::NAN,
        accuracy_pct: sw.accuracy_pct(),
        ci95_pct: sw.ci95_pct(),
    });
    Ok(points)
}

pub fn render(dataset: &str, points: &[ParetoPoint]) -> String {
    let mut out = format!("Fig 9 ({dataset}): energy-accuracy Pareto (AVSS)\n");
    out.push_str("series      cl  nJ/search  accuracy%  ±ci95\n");
    for p in points {
        out.push_str(&format!(
            "{:<10} {:>3}  {:>9.2}  {:>8.2}  {:>5.2}\n",
            p.series,
            p.cl,
            p.nj_per_search,
            p.accuracy_pct,
            p.ci95_pct
        ));
    }
    out
}

/// Best accuracy of a series (for the headline comparisons).
pub fn best_accuracy(points: &[ParetoPoint], series: &str) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.series == series)
        .map(|p| p.accuracy_pct)
        .fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweeps_match_paper_ranges() {
        let omni = sweep_points("omniglot");
        let mtmc = &omni.iter().find(|(e, _)| *e == Encoding::Mtmc).unwrap().1;
        assert!(mtmc.contains(&32), "Omniglot MTMC sweeps to CL=32");
        let cub = sweep_points("cub");
        let mtmc = &cub.iter().find(|(e, _)| *e == Encoding::Mtmc).unwrap().1;
        assert!(mtmc.contains(&25), "CUB MTMC sweeps to CL=25");
        let b4e = &omni.iter().find(|(e, _)| *e == Encoding::B4e).unwrap().1;
        assert!(b4e.iter().all(|&c| c <= 9), "B4E capped at CL=9");
        let b4we = &omni.iter().find(|(e, _)| *e == Encoding::B4we).unwrap().1;
        assert_eq!(b4we, &vec![1, 2, 3], "B4WE base lengths → words 1/5/21");
    }

    #[test]
    fn best_accuracy_picks_max() {
        let pts = vec![
            ParetoPoint {
                series: "a".into(),
                cl: 1,
                nj_per_search: 1.0,
                accuracy_pct: 50.0,
                ci95_pct: 0.0,
            },
            ParetoPoint {
                series: "a".into(),
                cl: 2,
                nj_per_search: 2.0,
                accuracy_pct: 70.0,
                ci95_pct: 0.0,
            },
        ];
        assert_eq!(best_accuracy(&pts, "a"), Some(70.0));
        assert_eq!(best_accuracy(&pts, "b"), None);
    }
}
