//! Fig. 2(b)/(c): simulated string-current distributions.
//!
//! (b) current vs *total* string mismatch level (0..72 in the paper's
//!     48-layer strings; 0..72 here too since 24 cells × mismatch ≤ 3),
//!     Monte-Carlo over random mismatch compositions with device
//!     variation on.
//! (c) current at fixed total mismatch 6, split by the *maximum* cell
//!     mismatch (1/2/3) — the bottleneck effect.

use crate::device::block::McamBlock;
use crate::device::variation::VariationModel;
use crate::device::McamParams;
use crate::metrics::Welford;
use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// Mean ± std of string current at one mismatch composition.
#[derive(Debug, Clone, Copy)]
pub struct CurrentPoint {
    pub total_mismatch: u32,
    pub max_mismatch: u32,
    pub mean_current: f64,
    pub std_current: f64,
    pub samples: usize,
}

/// Decompose `total` mismatch into 24 per-cell levels with maximum level
/// exactly `max_level` (if feasible). Returns None when infeasible.
fn compose(total: u32, max_level: u32, rng: &mut Rng) -> Option<[u8; CELLS_PER_STRING]> {
    if max_level == 0 {
        return if total == 0 { Some([0; CELLS_PER_STRING]) } else { None };
    }
    if total < max_level || total > (CELLS_PER_STRING as u32) * max_level {
        return None;
    }
    let mut cells = [0u8; CELLS_PER_STRING];
    // pin one cell at the max level, distribute the rest randomly < max
    cells[0] = max_level as u8;
    let mut remaining = total - max_level;
    let mut guard = 0;
    while remaining > 0 {
        let i = 1 + rng.below(CELLS_PER_STRING - 1);
        if (cells[i] as u32) < max_level {
            cells[i] += 1;
            remaining -= 1;
        }
        guard += 1;
        if guard > 100_000 {
            return None; // saturated
        }
    }
    rng.shuffle(&mut cells);
    Some(cells)
}

fn measure(
    cells_list: &[[u8; CELLS_PER_STRING]],
    variation: VariationModel,
    seed: u64,
) -> (f64, f64) {
    let params = McamParams::default();
    let mut block = McamBlock::new(cells_list.len(), params, variation, seed);
    for cells in cells_list {
        block.program_string(cells);
    }
    let wordline = [0u8; CELLS_PER_STRING];
    let mut out = Vec::new();
    block.search_range(&wordline, 0, cells_list.len(), &mut out);
    let mut w = Welford::default();
    for &c in &out {
        w.push(c);
    }
    (w.mean(), w.std())
}

/// Fig. 2(b): current distribution vs total string mismatch level.
pub fn fig2b(samples_per_level: usize, seed: u64) -> Vec<CurrentPoint> {
    let mut rng = Rng::new(seed);
    let variation = VariationModel::nand_default();
    let mut points = Vec::new();
    for total in (0..=72u32).step_by(6) {
        let mut compositions = Vec::new();
        // feasible max-mismatch range for this total
        let lo = total.div_ceil(CELLS_PER_STRING as u32);
        let hi = total.min(3);
        for _ in 0..samples_per_level {
            let max_level = if total == 0 {
                0
            } else {
                lo + rng.below((hi - lo + 1) as usize) as u32
            };
            if let Some(cells) = compose(total, max_level, &mut rng) {
                compositions.push(cells);
            }
        }
        if compositions.is_empty() {
            continue;
        }
        let (mean, std) = measure(&compositions, variation, seed ^ total as u64);
        points.push(CurrentPoint {
            total_mismatch: total,
            max_mismatch: 0, // mixed
            mean_current: mean,
            std_current: std,
            samples: compositions.len(),
        });
    }
    points
}

/// Fig. 2(c): current at total mismatch 6, by max mismatch level 1/2/3.
pub fn fig2c(samples_per_level: usize, seed: u64) -> Vec<CurrentPoint> {
    let mut rng = Rng::new(seed);
    let variation = VariationModel::nand_default();
    let mut points = Vec::new();
    for max_level in 1..=3u32 {
        let mut compositions = Vec::new();
        for _ in 0..samples_per_level {
            if let Some(cells) = compose(6, max_level, &mut rng) {
                compositions.push(cells);
            }
        }
        let (mean, std) = measure(&compositions, variation, seed ^ max_level as u64);
        points.push(CurrentPoint {
            total_mismatch: 6,
            max_mismatch: max_level,
            mean_current: mean,
            std_current: std,
            samples: compositions.len(),
        });
    }
    points
}

pub fn render() -> String {
    let mut out = String::from("Fig 2(b): current vs total string mismatch (noisy device)\n");
    out.push_str("total_mismatch  mean_I  std_I\n");
    for p in fig2b(400, 0xF19_2B) {
        out.push_str(&format!(
            "{:>14}  {:.4}  {:.4}\n",
            p.total_mismatch, p.mean_current, p.std_current
        ));
    }
    out.push_str("\nFig 2(c): current at total mismatch 6, by max mismatch level\n");
    out.push_str("max_mismatch  mean_I  std_I\n");
    for p in fig2c(400, 0xF19_2C) {
        out.push_str(&format!(
            "{:>12}  {:.4}  {:.4}\n",
            p.max_mismatch, p.mean_current, p.std_current
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2b_current_decreases_with_total_mismatch() {
        let points = fig2b(200, 1);
        assert!(points.len() >= 10);
        for w in points.windows(2) {
            assert!(
                w[1].mean_current < w[0].mean_current,
                "current must fall: {} vs {}",
                w[0].mean_current,
                w[1].mean_current
            );
        }
        assert_eq!(points[0].total_mismatch, 0);
        // all-match strings draw ~I_max = 1.0 (mean preserved under
        // symmetric lognormal-in-log noise up to bias)
        assert!((points[0].mean_current - 1.0).abs() < 0.1);
    }

    #[test]
    fn fig2c_bottleneck_ordering() {
        // Paper: same total mismatch, larger max mismatch → smaller current.
        let points = fig2c(300, 2);
        assert_eq!(points.len(), 3);
        assert!(points[0].mean_current > points[1].mean_current);
        assert!(points[1].mean_current > points[2].mean_current);
    }

    #[test]
    fn fig2b_variation_produces_spread() {
        let points = fig2b(200, 3);
        // strings with mismatch show current sigma from device variation
        assert!(points.iter().skip(1).all(|p| p.std_current > 0.0));
    }

    #[test]
    fn compose_respects_constraints() {
        let mut rng = Rng::new(4);
        for (total, max) in [(6, 1), (6, 2), (6, 3), (72, 3), (0, 0)] {
            if let Some(cells) = compose(total, max, &mut rng) {
                let sum: u32 = cells.iter().map(|&c| c as u32).sum();
                let mx = cells.iter().copied().max().unwrap() as u32;
                assert_eq!(sum, total);
                assert_eq!(mx, max);
            } else {
                panic!("composition ({total},{max}) should be feasible");
            }
        }
        assert!(compose(5, 0, &mut rng).is_none());
        assert!(compose(100, 1, &mut rng).is_none());
    }
}
