//! `fig_routing` — recall/energy tradeoff of the hierarchical shard
//! routing tier (DESIGN.md §Routing; like `fig_cascade` this figure has
//! no paper counterpart — it evaluates the serving-side scale-out this
//! repo adds on top of the paper's AVSS result).
//!
//! A hierarchically-clustered synthetic support set (class prototypes
//! drawn around per-group centres, groups contiguous in slot order so
//! they align with shard ownership) is programmed into an ideal-device
//! MTMC/AVSS engine at several shard counts. For each shard count the
//! sweep measures the flat scan (every shard sensed — the exact
//! baseline) and routed scans at increasing probe budgets. Every point
//! reports the **honest** sensed-string count per query straight from
//! the energy ledger (representative senses billed), the shard senses
//! per query, the reduction versus the flat scan, classification
//! accuracy, and top-1 agreement with the flat scan (recall@1 of the
//! routed search against its own exact counterpart).

use crate::metrics::CsvTable;
use crate::search::engine::{EngineConfig, SearchEngine};
use crate::search::routing::RoutingConfig;
use crate::search::{SearchMode, SearchRequest};
use crate::testutil::Rng;
use anyhow::Result;
use crate::encoding::Encoding;

const DIMS: usize = 48;
const CL: usize = 8;
const CLIP: f64 = 3.0;
/// Spread of class prototypes around their group centre (the coarse
/// structure routing exploits).
const GROUP_SPREAD: f64 = 0.25;
/// Spread of support members around their class prototype.
const MEMBER_SPREAD: f64 = 0.03;
/// Spread of queries around their class prototype.
const QUERY_SPREAD: f64 = 0.05;

/// Sweep sizing. [`Scale::paper`] is the 10⁴-slot operating point the
/// `experiment`/bench harnesses run; [`Scale::smoke`] is the CI-sized
/// episode behind the acceptance test.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub classes: usize,
    pub per_class: usize,
    pub n_queries: usize,
    /// Shard counts measured (support is clustered into
    /// `shard_counts.last()` groups so every count aligns with the
    /// cluster structure).
    pub shard_counts: &'static [usize],
    /// Routed probe budgets measured per shard count.
    pub probe_counts: &'static [usize],
}

impl Scale {
    /// 512 classes × 20 members = 10,240 slots across 16–64 shards.
    pub fn paper() -> Scale {
        Scale {
            classes: 512,
            per_class: 20,
            n_queries: 48,
            shard_counts: &[16, 32, 64],
            probe_counts: &[1, 2, 4, 8],
        }
    }

    /// 64 classes × 8 members = 512 slots across 16 shards.
    pub fn smoke() -> Scale {
        Scale {
            classes: 64,
            per_class: 8,
            n_queries: 128,
            shard_counts: &[16],
            probe_counts: &[2, 4],
        }
    }

    fn groups(&self) -> usize {
        *self.shard_counts.last().expect("at least one shard count")
    }
}

/// One measured sweep point (`probes == 0` is the flat baseline).
#[derive(Debug, Clone)]
pub struct RoutingPoint {
    pub label: String,
    pub shards: usize,
    /// Probe budget (0 for the flat scan).
    pub probes: usize,
    /// Strings sensed per query (energy-ledger actuals, representative
    /// senses included).
    pub sensed_per_query: f64,
    /// Shard sense passes per query (flat = every shard).
    pub shard_senses_per_query: f64,
    /// Flat sensed strings / this point's sensed strings (same shard
    /// count).
    pub reduction: f64,
    /// Mean `RoutingStats::iterations_saved` per query (0 for flat).
    pub saved_per_query: f64,
    pub accuracy_pct: f64,
    /// Top-1 label agreement with the flat scan at the same shard count
    /// — routed recall@1 against its exact counterpart.
    pub flat_agreement_pct: f64,
    /// Pareto-efficient within its shard count (no point senses no more
    /// and scores strictly better).
    pub frontier: bool,
}

/// The full sweep over shard counts × probe budgets.
#[derive(Debug, Clone)]
pub struct RoutingSweep {
    pub scale_slots: usize,
    pub points: Vec<RoutingPoint>,
}

impl RoutingSweep {
    pub fn point(&self, shards: usize, probes: usize) -> Option<&RoutingPoint> {
        self.points.iter().find(|p| p.shards == shards && p.probes == probes)
    }
}

/// Deterministic hierarchically-clustered episode: group centres,
/// class prototypes around them (classes contiguous per group, so slot
/// order aligns with shard ownership), support members and queries
/// jittered around the prototypes.
fn synth_hierarchical(
    scale: &Scale,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<u32>, Vec<Vec<f32>>, Vec<u32>) {
    let groups = scale.groups();
    assert_eq!(scale.classes % groups, 0, "classes must split evenly over groups");
    let per_group = scale.classes / groups;
    let mut rng = Rng::new(seed);
    let clamp = |v: f64| v.clamp(0.0, CLIP) as f32;
    let mut protos = Vec::with_capacity(scale.classes);
    let mut support = Vec::with_capacity(scale.classes * scale.per_class);
    let mut labels = Vec::with_capacity(scale.classes * scale.per_class);
    for _ in 0..groups {
        let centre: Vec<f64> = (0..DIMS).map(|_| rng.range_f64(0.4, 2.6)).collect();
        for _ in 0..per_group {
            let proto: Vec<f64> =
                centre.iter().map(|&c| c + GROUP_SPREAD * rng.gaussian()).collect();
            let class = protos.len() as u32;
            for _ in 0..scale.per_class {
                support.push(
                    proto.iter().map(|&p| clamp(p + MEMBER_SPREAD * rng.gaussian())).collect(),
                );
                labels.push(class);
            }
            protos.push(proto);
        }
    }
    let mut queries = Vec::with_capacity(scale.n_queries);
    let mut truth = Vec::with_capacity(scale.n_queries);
    for i in 0..scale.n_queries {
        let class = i * scale.classes / scale.n_queries;
        queries.push(
            protos[class].iter().map(|&p| clamp(p + QUERY_SPREAD * rng.gaussian())).collect(),
        );
        truth.push(class as u32);
    }
    (support, labels, queries, truth)
}

/// Measure one (shard count, probe budget) point. Returns per-query
/// top-1 labels plus (sensed/query, shard senses/query, saved/query,
/// accuracy%).
fn measure(
    shards: usize,
    probes: Option<usize>,
    support: &[Vec<f32>],
    labels: &[u32],
    queries: &[Vec<f32>],
    truth: &[u32],
    seed: u64,
) -> Result<(Vec<Option<u32>>, f64, f64, f64, f64)> {
    let refs: Vec<&[f32]> = support.iter().map(|e| e.as_slice()).collect();
    let cfg = EngineConfig::new(Encoding::Mtmc, CL, SearchMode::Avss, CLIP)
        .ideal()
        .with_seed(seed)
        .with_shards(shards);
    let mut engine = SearchEngine::new(cfg, DIMS, refs.len())?;
    engine.program_support(&refs, labels)?;
    engine.set_routing(probes.map(RoutingConfig::probe_count))?;
    let mut preds = Vec::with_capacity(queries.len());
    let mut correct = 0usize;
    let mut shard_senses = 0u64;
    let mut saved = 0i64;
    for (query, &want) in queries.iter().zip(truth) {
        let response = engine.search(&SearchRequest::new(query))?;
        let got = response.top().map(|h| h.label);
        if got == Some(want) {
            correct += 1;
        }
        match &response.routing {
            Some(stats) => {
                shard_senses += stats.shards_sensed as u64;
                saved += stats.iterations_saved;
            }
            None => shard_senses += shards as u64,
        }
        preds.push(got);
    }
    let n = queries.len() as f64;
    Ok((
        preds,
        engine.energy().sensed_strings as f64 / n,
        shard_senses as f64 / n,
        saved as f64 / n,
        100.0 * correct as f64 / n,
    ))
}

/// Run the sweep at a given scale. Deterministic for a fixed seed
/// (ideal device).
pub fn run_at(scale: Scale, seed: u64) -> Result<RoutingSweep> {
    let (support, labels, queries, truth) = synth_hierarchical(&scale, seed);
    let mut points = Vec::new();
    for &shards in scale.shard_counts {
        let (flat_preds, flat_sensed, flat_shards, _, flat_acc) =
            measure(shards, None, &support, &labels, &queries, &truth, seed)?;
        points.push(RoutingPoint {
            label: format!("{shards} shards, flat"),
            shards,
            probes: 0,
            sensed_per_query: flat_sensed,
            shard_senses_per_query: flat_shards,
            reduction: 1.0,
            saved_per_query: 0.0,
            accuracy_pct: flat_acc,
            flat_agreement_pct: 100.0,
            frontier: false,
        });
        for &probes in scale.probe_counts {
            if probes >= shards {
                continue; // probing every shard is the flat bypass
            }
            let (preds, sensed, shard_senses, saved, acc) =
                measure(shards, Some(probes), &support, &labels, &queries, &truth, seed)?;
            let agree = preds.iter().zip(&flat_preds).filter(|(a, b)| a == b).count();
            points.push(RoutingPoint {
                label: format!("{shards} shards, probe {probes}"),
                shards,
                probes,
                sensed_per_query: sensed,
                shard_senses_per_query: shard_senses,
                reduction: flat_sensed / sensed.max(1.0),
                saved_per_query: saved,
                accuracy_pct: acc,
                flat_agreement_pct: 100.0 * agree as f64 / queries.len() as f64,
                frontier: false,
            });
        }
    }

    // Pareto frontier within each shard count: dominated = someone
    // senses no more and scores strictly better (or senses strictly
    // less at equal accuracy).
    for i in 0..points.len() {
        let dominated = (0..points.len()).any(|j| {
            j != i
                && points[j].shards == points[i].shards
                && points[j].sensed_per_query <= points[i].sensed_per_query
                && points[j].accuracy_pct >= points[i].accuracy_pct
                && (points[j].sensed_per_query < points[i].sensed_per_query
                    || points[j].accuracy_pct > points[i].accuracy_pct)
        });
        points[i].frontier = !dominated;
    }

    Ok(RoutingSweep { scale_slots: support.len(), points })
}

/// Run the paper-scale sweep (the `experiment --filter fig_routing` /
/// bench entry point).
pub fn run(seed: u64) -> Result<RoutingSweep> {
    run_at(Scale::paper(), seed)
}

/// Render the sweep as a text table (grouped by shard count, walking
/// down each group's probe budgets).
pub fn render(sweep: &RoutingSweep) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "fig_routing — shard-routing frontier ({} slots, ideal device, honest ledger)\n",
        sweep.scale_slots
    ));
    out.push_str(
        "config                 | sensed/q | shard senses/q | reduction | saved/q | acc%   | vs flat% | frontier\n",
    );
    for p in &sweep.points {
        out.push_str(&format!(
            "{:<22} | {:>8.0} | {:>14.1} | {:>8.2}x | {:>7.0} | {:>6.2} | {:>8.2} | {}\n",
            p.label,
            p.sensed_per_query,
            p.shard_senses_per_query,
            p.reduction,
            p.saved_per_query,
            p.accuracy_pct,
            p.flat_agreement_pct,
            if p.frontier { "*" } else { "" }
        ));
    }
    out
}

/// Machine-readable CSV rows (mirrors [`render`]).
pub fn csv(sweep: &RoutingSweep) -> CsvTable {
    let mut table = CsvTable::new(&[
        "label",
        "shards",
        "probes",
        "sensed_per_query",
        "shard_senses_per_query",
        "reduction",
        "saved_per_query",
        "accuracy_pct",
        "flat_agreement_pct",
        "frontier",
    ]);
    for p in &sweep.points {
        table.row(&[
            p.label.clone(),
            p.shards.to_string(),
            p.probes.to_string(),
            format!("{:.1}", p.sensed_per_query),
            format!("{:.2}", p.shard_senses_per_query),
            format!("{:.3}", p.reduction),
            format!("{:.1}", p.saved_per_query),
            format!("{:.3}", p.accuracy_pct),
            format!("{:.3}", p.flat_agreement_pct),
            (p.frontier as u8).to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_meets_acceptance_frontier() {
        // The fig_routing acceptance criteria, asserted as a test so the
        // tradeoff can never silently regress: probing 4 of 16 shards on
        // a clustered 512-slot episode must cut shard senses 4× (and
        // sensed strings ≥3.5× — representatives are billed) at ≤1%
        // accuracy cost versus the flat scan.
        let sweep = run_at(Scale::smoke(), 0xC0A25E).unwrap();
        let flat = sweep.point(16, 0).expect("flat baseline measured");
        assert_eq!(flat.reduction, 1.0);
        assert_eq!(flat.shard_senses_per_query, 16.0, "flat senses every shard");
        let routed = sweep.point(16, 4).expect("probe-4 point measured");
        assert_eq!(routed.shard_senses_per_query, 4.0, "router dispatches 4 shards");
        assert!(
            flat.shard_senses_per_query / routed.shard_senses_per_query >= 4.0 - 1e-9,
            "≥4x sensed-shard reduction"
        );
        assert!(
            routed.reduction >= 3.5,
            "string-sense reduction with reps billed: {:.2}x",
            routed.reduction
        );
        // representative senses are billed: routed senses strictly more
        // than a quarter of the flat strings
        assert!(routed.sensed_per_query > flat.sensed_per_query * 4.0 / 16.0);
        assert!(routed.saved_per_query > 0.0, "routing must save net work here");
        assert!(
            flat.accuracy_pct - routed.accuracy_pct <= 1.0 + 1e-9,
            "accuracy cost too large: flat {:.2}% vs routed {:.2}%",
            flat.accuracy_pct,
            routed.accuracy_pct
        );
        assert!(
            routed.flat_agreement_pct >= 95.0,
            "routed top-1 must track the flat scan: {:.2}%",
            routed.flat_agreement_pct
        );
        // rendering (text + CSV) covers the same sweep
        let text = render(&sweep);
        assert!(text.contains("16 shards, flat"));
        assert!(text.contains("16 shards, probe 4"));
        let table = csv(&sweep);
        assert!(table.render().contains("shard_senses_per_query"));
    }
}
