//! Experiment harnesses: one module per table/figure of the paper
//! (DESIGN.md §5 maps each to its bench target). Every harness returns
//! typed rows plus a rendered text table so `cargo bench` regenerates the
//! paper's artifacts with paper-vs-measured annotations inline.

pub mod ablation;
pub mod fig2;
pub mod fig3_5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod fig_cascade;
pub mod fig_faults;
pub mod fig_routing;
pub mod headline;
pub mod table1;
pub mod table2;

use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::fsl::{episode_rng, evaluate_episode, sample_episode};
use crate::metrics::AccuracyMeter;
use crate::search::cascade::CascadeConfig;
use crate::search::engine::{EngineConfig, SearchEngine};
use crate::search::routing::RoutingConfig;
use crate::search::SearchMode;
use anyhow::Result;

/// Episode settings for an experiment run (paper way/shot settings with a
/// budgeted episode/query count).
#[derive(Debug, Clone, Copy)]
pub struct EpisodeSettings {
    pub n_way: usize,
    pub k_shot: usize,
    pub n_query: usize,
    pub episodes: usize,
    pub seed: u64,
}

impl EpisodeSettings {
    /// Omniglot: the paper's 200-way 10-shot many-class setting.
    pub fn omniglot() -> EpisodeSettings {
        EpisodeSettings { n_way: 200, k_shot: 10, n_query: 2, episodes: 3, seed: 0xE9 }
    }

    /// CUB: the paper's 50-way 5-shot setting.
    pub fn cub() -> EpisodeSettings {
        EpisodeSettings { n_way: 50, k_shot: 5, n_query: 5, episodes: 4, seed: 0xE9 }
    }

    pub fn for_dataset(dataset: &str) -> EpisodeSettings {
        match dataset {
            "cub" => Self::cub(),
            _ => Self::omniglot(),
        }
    }

    /// Lighter settings for smoke tests.
    pub fn smoke(mut self) -> EpisodeSettings {
        self.n_way = self.n_way.min(20);
        self.k_shot = self.k_shot.min(3);
        self.n_query = 1;
        self.episodes = 1;
        self
    }
}

/// Result of an MCAM episode evaluation run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub accuracy: AccuracyMeter,
    pub nj_per_search: f64,
    /// Configured-mode full-scan iterations — the **upper bound**
    /// (`SearchEngine::max_iterations_per_search`); cascade runs execute
    /// fewer, see [`Self::avg_iterations_per_search`].
    pub iterations_per_search: usize,
    /// Word-line iterations actually executed per search (== the bound
    /// for plain scans; smaller under a cascade).
    pub avg_iterations_per_search: f64,
    /// Strings actually sensed per search (honest energy-ledger count).
    pub sensed_strings_per_search: f64,
    /// Device-bound throughput at the *measured* iteration count.
    pub throughput_per_s: f64,
}

/// Optional engine features threaded through [`run_mcam_eval_opts`]
/// (avoids a fresh positional argument per subsystem).
#[derive(Debug, Clone, Default)]
pub struct EvalOpts<'a> {
    /// Progressive-precision cascade schedule, if any.
    pub cascade: Option<&'a CascadeConfig>,
    /// MCAM shards the support set is split across (`0`/`1` = one block).
    pub shards: usize,
    /// Hierarchical shard routing policy, if any.
    pub routing: Option<RoutingConfig>,
}

/// Evaluate an engine configuration over episodes of (dataset, variant)
/// test embeddings — [`run_mcam_eval_opts`] with every option off.
pub fn run_mcam_eval(
    store: &ArtifactStore,
    dataset: &str,
    variant: &str,
    encoding: Encoding,
    cl: usize,
    mode: SearchMode,
    variation: VariationModel,
    settings: EpisodeSettings,
) -> Result<RunResult> {
    run_mcam_eval_opts(
        store,
        dataset,
        variant,
        encoding,
        cl,
        mode,
        variation,
        settings,
        EvalOpts::default(),
    )
}

/// Evaluate an engine configuration over episodes of (dataset, variant)
/// test embeddings, optionally through a progressive-precision cascade
/// and/or a routed shard fleet ([`EvalOpts`]).
#[allow(clippy::too_many_arguments)]
pub fn run_mcam_eval_opts(
    store: &ArtifactStore,
    dataset: &str,
    variant: &str,
    encoding: Encoding,
    cl: usize,
    mode: SearchMode,
    variation: VariationModel,
    settings: EpisodeSettings,
    opts: EvalOpts<'_>,
) -> Result<RunResult> {
    let ds = store.embeddings(dataset, variant, "test")?;
    let clip = store.clip(dataset, variant)?;
    let cfg = EngineConfig::new(encoding, cl, mode, clip)
        .with_variation(variation)
        .with_seed(settings.seed)
        .with_shards(opts.shards.max(1));
    let mut engine =
        SearchEngine::new(cfg, ds.dims, settings.n_way * settings.k_shot)?;
    engine.set_cascade(opts.cascade.cloned())?;
    engine.set_routing(opts.routing.clone())?;
    let mut accuracy = AccuracyMeter::default();
    for ep_idx in 0..settings.episodes {
        let mut rng = episode_rng(settings.seed, ep_idx as u64);
        let ep = sample_episode(&ds, &mut rng, settings.n_way, settings.k_shot, settings.n_query);
        let (correct, total) = evaluate_episode(&mut engine, &ds, &ep)?;
        accuracy.push_episode(correct, total);
    }
    let iterations = engine.max_iterations_per_search();
    let avg_iterations = engine.timing().avg_iterations_per_search();
    let searches = engine.timing().searches.max(1);
    Ok(RunResult {
        accuracy,
        nj_per_search: engine.energy().nj_per_search(),
        iterations_per_search: iterations,
        avg_iterations_per_search: avg_iterations,
        sensed_strings_per_search: engine.energy().sensed_strings as f64 / searches as f64,
        throughput_per_s: crate::device::timing::SearchTiming::throughput_per_s_avg(
            avg_iterations,
        ),
    })
}

/// Evaluate the software (float prototypical-network L1) baseline on the
/// same episode stream.
pub fn run_software_baseline(
    store: &ArtifactStore,
    dataset: &str,
    variant: &str,
    settings: EpisodeSettings,
) -> Result<AccuracyMeter> {
    let ds = store.embeddings(dataset, variant, "test")?;
    let mut accuracy = AccuracyMeter::default();
    for ep_idx in 0..settings.episodes {
        let mut rng = episode_rng(settings.seed, ep_idx as u64);
        let ep = sample_episode(&ds, &mut rng, settings.n_way, settings.k_shot, settings.n_query);
        let support: Vec<&[f32]> =
            ep.support.iter().map(|&(row, _)| ds.embedding(row)).collect();
        let labels: Vec<u32> = ep.support.iter().map(|&(_, l)| l).collect();
        let mut correct = 0;
        for &(row, truth) in &ep.queries {
            let pred = crate::baselines::protonet_predict(
                &support,
                &labels,
                ds.embedding(row),
                crate::baselines::Metric::L1,
            );
            if pred == truth {
                correct += 1;
            }
        }
        accuracy.push_episode(correct, ep.queries.len());
    }
    Ok(accuracy)
}

/// Render a percentage with CI for tables.
pub fn pct(meter: &AccuracyMeter) -> String {
    format!("{:.2}±{:.2}", meter.accuracy_pct(), meter.ci95_pct())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn settings_match_paper() {
        let o = EpisodeSettings::omniglot();
        assert_eq!((o.n_way, o.k_shot), (200, 10));
        let c = EpisodeSettings::cub();
        assert_eq!((c.n_way, c.k_shot), (50, 5));
        assert_eq!(EpisodeSettings::for_dataset("cub").n_way, 50);
    }

    #[test]
    fn smoke_shrinks() {
        let s = EpisodeSettings::omniglot().smoke();
        assert!(s.n_way <= 20 && s.episodes == 1);
    }
}
