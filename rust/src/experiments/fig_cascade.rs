//! `fig_cascade` — the accuracy-vs-iterations tradeoff frontier of the
//! progressive-precision cascade (DESIGN.md §Cascade; this figure has no
//! paper counterpart — it evaluates the serving-side scheduling this
//! repo adds on top of the paper's AVSS result).
//!
//! A synthetic many-class support set (512 slots: 64 classes × 8
//! members, 48-d) is programmed into an ideal-device MTMC/AVSS engine.
//! The sweep walks two-stage cascades — coarse column-prefix pass over
//! all slots, full-precision refine of the shortlist — across coarse
//! widths and shortlist sizes, plus one safety-margin point that early
//! exits. For every point it reports the **honest** sensed-string count
//! per query (straight from the energy ledger), the reduction versus the
//! full AVSS scan, classification accuracy against the true labels, and
//! agreement with the exact-float nearest-support oracle
//! ([`crate::baselines::FloatBaseline`]-equivalent decision rule).
//! Pareto-efficient points are flagged; sorted by sensed strings they
//! form the monotone iterations-vs-accuracy frontier.

use crate::baselines::{nearest_support_predict, Metric};
use crate::encoding::Encoding;
use crate::metrics::CsvTable;
use crate::search::cascade::{CascadeConfig, Shortlist};
use crate::search::engine::{EngineConfig, SearchEngine};
use crate::search::{SearchMode, SearchRequest};
use crate::testutil::Rng;
use anyhow::Result;

/// Synth operating point: many-class (512-slot) support at the MTMC/AVSS
/// setting, small enough that the whole sweep runs in a CI smoke step.
const DIMS: usize = 48;
const CLASSES: usize = 64;
const PER_CLASS: usize = 8;
const QUERIES_PER_CLASS: usize = 4;
const CL: usize = 8;
const CLIP: f64 = 3.0;
const SPREAD: f64 = 0.03;

/// One measured sweep point.
#[derive(Debug, Clone)]
pub struct CascadePoint {
    pub label: String,
    /// Coarse-stage column prefix (0 for the full-scan baseline).
    pub coarse_columns: usize,
    /// Shortlist carried into the refine stage (0 for the full scan).
    pub shortlist: usize,
    pub safety_margin: f64,
    /// Strings sensed per query (energy-ledger actuals).
    pub sensed_per_query: f64,
    /// Full-scan sensed strings / this point's sensed strings.
    pub reduction: f64,
    /// Word-line iterations actually executed per query.
    pub avg_iterations: f64,
    pub accuracy_pct: f64,
    /// Top-1 label agreement with the exact-float L1 nearest-support
    /// oracle.
    pub oracle_agreement_pct: f64,
    pub early_exit_pct: f64,
    /// On the Pareto frontier (no point senses no more and scores
    /// strictly better).
    pub frontier: bool,
}

/// The full sweep: baseline + cascade points + the oracle reference.
#[derive(Debug, Clone)]
pub struct CascadeSweep {
    /// Exact-float L1 nearest-support accuracy on the same episode.
    pub oracle_accuracy_pct: f64,
    /// Strings a full configured-mode scan senses per query.
    pub full_scan_sensed: f64,
    /// Measured points; `points[0]` is the full-scan baseline.
    pub points: Vec<CascadePoint>,
}

impl CascadeSweep {
    /// Full-scan baseline accuracy.
    pub fn full_scan_accuracy_pct(&self) -> f64 {
        self.points[0].accuracy_pct
    }

    /// The best-accuracy point at ≥ `min_reduction`× sensed-string
    /// reduction — the acceptance probe of the `perf_cascade` bench.
    pub fn best_at_reduction(&self, min_reduction: f64) -> Option<&CascadePoint> {
        self.points
            .iter()
            .filter(|p| p.reduction >= min_reduction)
            .max_by(|a, b| a.accuracy_pct.total_cmp(&b.accuracy_pct))
    }
}

/// Deterministic clustered synth episode: protos uniform in the
/// quantizer range, members and queries jittered around them.
fn synth_episode(seed: u64) -> (Vec<Vec<f32>>, Vec<u32>, Vec<Vec<f32>>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let mut support = Vec::with_capacity(CLASSES * PER_CLASS);
    let mut labels = Vec::with_capacity(CLASSES * PER_CLASS);
    let mut queries = Vec::with_capacity(CLASSES * QUERIES_PER_CLASS);
    let mut truth = Vec::with_capacity(CLASSES * QUERIES_PER_CLASS);
    for c in 0..CLASSES {
        let proto: Vec<f64> = (0..DIMS).map(|_| rng.range_f64(0.2, 2.8)).collect();
        for _ in 0..PER_CLASS {
            support.push(jitter(&proto, &mut rng));
            labels.push(c as u32);
        }
        for _ in 0..QUERIES_PER_CLASS {
            queries.push(jitter(&proto, &mut rng));
            truth.push(c as u32);
        }
    }
    (support, labels, queries, truth)
}

fn jitter(proto: &[f64], rng: &mut Rng) -> Vec<f32> {
    proto.iter().map(|&p| (p + SPREAD * rng.gaussian()).max(0.0) as f32).collect()
}

/// Measure one engine configuration (optionally cascaded) over the
/// episode. Returns (accuracy%, oracle-agreement%, sensed/query,
/// avg iterations, early-exit%).
fn measure(
    cascade: Option<CascadeConfig>,
    support: &[Vec<f32>],
    labels: &[u32],
    queries: &[Vec<f32>],
    truth: &[u32],
    oracle: &[u32],
    seed: u64,
) -> Result<(f64, f64, f64, f64, f64)> {
    let refs: Vec<&[f32]> = support.iter().map(|e| e.as_slice()).collect();
    let cfg = EngineConfig::new(Encoding::Mtmc, CL, SearchMode::Avss, CLIP)
        .ideal()
        .with_seed(seed);
    let mut engine = SearchEngine::new(cfg, DIMS, refs.len())?;
    engine.program_support(&refs, labels)?;
    engine.set_cascade(cascade)?;
    let mut correct = 0usize;
    let mut agree = 0usize;
    let mut exits = 0usize;
    for (q, (query, &want)) in queries.iter().zip(truth).enumerate() {
        let response = engine.search(&SearchRequest::new(query))?;
        let got = response.top().map(|h| h.label);
        if got == Some(want) {
            correct += 1;
        }
        if got == Some(oracle[q]) {
            agree += 1;
        }
        if response.cascade.as_ref().is_some_and(|c| c.early_exited) {
            exits += 1;
        }
    }
    let n = queries.len() as f64;
    Ok((
        100.0 * correct as f64 / n,
        100.0 * agree as f64 / n,
        engine.energy().sensed_strings as f64 / n,
        engine.timing().avg_iterations_per_search(),
        100.0 * exits as f64 / n,
    ))
}

/// Run the sweep. Deterministic for a fixed seed (ideal device).
pub fn run(seed: u64) -> Result<CascadeSweep> {
    let (support, labels, queries, truth) = synth_episode(seed);
    let refs: Vec<&[f32]> = support.iter().map(|e| e.as_slice()).collect();
    let oracle: Vec<u32> = queries
        .iter()
        .map(|q| nearest_support_predict(&refs, labels.as_slice(), q, Metric::L1))
        .collect();
    let oracle_accuracy_pct = 100.0
        * oracle.iter().zip(&truth).filter(|(a, b)| a == b).count() as f64
        / truth.len() as f64;

    // (coarse columns, shortlist, safety margin) sweep; margin == inf
    // never exits early. The (2, 64, 8.0) point shows the margin lever.
    let sweep: [(usize, usize, f64); 9] = [
        (4, 128, f64::INFINITY),
        (4, 64, f64::INFINITY),
        (2, 128, f64::INFINITY),
        (2, 64, f64::INFINITY),
        (2, 64, 8.0),
        (2, 32, f64::INFINITY),
        (1, 64, f64::INFINITY),
        (1, 32, f64::INFINITY),
        (1, 16, f64::INFINITY),
    ];

    let mut points = Vec::with_capacity(sweep.len() + 1);
    let (acc, agree, sensed, iters, exits) =
        measure(None, &support, &labels, &queries, &truth, &oracle, seed)?;
    let full_scan_sensed = sensed;
    points.push(CascadePoint {
        label: "full AVSS scan".to_string(),
        coarse_columns: 0,
        shortlist: 0,
        safety_margin: f64::INFINITY,
        sensed_per_query: sensed,
        reduction: 1.0,
        avg_iterations: iters,
        accuracy_pct: acc,
        oracle_agreement_pct: agree,
        early_exit_pct: exits,
        frontier: false,
    });
    for (columns, shortlist, margin) in sweep {
        let cascade = CascadeConfig::two_stage(columns, Shortlist::Count(shortlist))
            .with_safety_margin(margin);
        let (acc, agree, sensed, iters, exits) =
            measure(Some(cascade), &support, &labels, &queries, &truth, &oracle, seed)?;
        let margin_tag = if margin.is_finite() {
            format!(" margin {margin:.0}")
        } else {
            String::new()
        };
        points.push(CascadePoint {
            label: format!("cols {columns}/{CL} keep {shortlist}{margin_tag}"),
            coarse_columns: columns,
            shortlist,
            safety_margin: margin,
            sensed_per_query: sensed,
            reduction: full_scan_sensed / sensed.max(1.0),
            avg_iterations: iters,
            accuracy_pct: acc,
            oracle_agreement_pct: agree,
            early_exit_pct: exits,
            frontier: false,
        });
    }

    // Pareto frontier: dominated = someone senses no more and scores
    // strictly better (or senses strictly less at equal accuracy).
    for i in 0..points.len() {
        let dominated = (0..points.len()).any(|j| {
            j != i
                && points[j].sensed_per_query <= points[i].sensed_per_query
                && points[j].accuracy_pct >= points[i].accuracy_pct
                && (points[j].sensed_per_query < points[i].sensed_per_query
                    || points[j].accuracy_pct > points[i].accuracy_pct)
        });
        points[i].frontier = !dominated;
    }

    Ok(CascadeSweep { oracle_accuracy_pct, full_scan_sensed, points })
}

/// Render the sweep as a text table (sorted by sensed strings,
/// descending — walking down the frontier).
pub fn render(sweep: &CascadeSweep) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "fig_cascade — prune-and-refine frontier ({} slots, {}-way synth, ideal device)\n",
        CLASSES * PER_CLASS,
        CLASSES
    ));
    out.push_str(&format!(
        "float L1 nearest-support oracle accuracy: {:.2}%\n",
        sweep.oracle_accuracy_pct
    ));
    out.push_str(
        "config                      | sensed/q | reduction | avg iters | acc%   | oracle% | exit% | frontier\n",
    );
    let mut rows: Vec<&CascadePoint> = sweep.points.iter().collect();
    rows.sort_by(|a, b| b.sensed_per_query.total_cmp(&a.sensed_per_query));
    for p in rows {
        out.push_str(&format!(
            "{:<27} | {:>8.0} | {:>8.2}x | {:>9.2} | {:>6.2} | {:>7.2} | {:>5.1} | {}\n",
            p.label,
            p.sensed_per_query,
            p.reduction,
            p.avg_iterations,
            p.accuracy_pct,
            p.oracle_agreement_pct,
            p.early_exit_pct,
            if p.frontier { "*" } else { "" }
        ));
    }
    out
}

/// Machine-readable CSV rows (mirrors [`render`]).
pub fn csv(sweep: &CascadeSweep) -> CsvTable {
    let mut table = CsvTable::new(&[
        "label",
        "coarse_columns",
        "shortlist",
        "sensed_per_query",
        "reduction",
        "avg_iterations",
        "accuracy_pct",
        "oracle_agreement_pct",
        "early_exit_pct",
        "frontier",
    ]);
    for p in &sweep.points {
        table.row(&[
            p.label.clone(),
            p.coarse_columns.to_string(),
            p.shortlist.to_string(),
            format!("{:.1}", p.sensed_per_query),
            format!("{:.3}", p.reduction),
            format!("{:.3}", p.avg_iterations),
            format!("{:.3}", p.accuracy_pct),
            format!("{:.3}", p.oracle_agreement_pct),
            format!("{:.3}", p.early_exit_pct),
            (p.frontier as u8).to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_meets_acceptance_frontier() {
        // The fig_cascade acceptance criteria, asserted as a test so the
        // tradeoff can never silently regress: ≥2× sensed-string
        // reduction at ≤0.5% accuracy drop versus the full AVSS scan.
        let sweep = run(0xCA5CADE).unwrap();
        assert_eq!(sweep.points[0].reduction, 1.0);
        assert!(sweep.full_scan_sensed > 0.0);
        let best = sweep.best_at_reduction(2.0).expect("sweep has a ≥2x point");
        assert!(best.reduction >= 2.0, "reduction {:.2}", best.reduction);
        assert!(
            sweep.full_scan_accuracy_pct() - best.accuracy_pct <= 0.5 + 1e-9,
            "accuracy drop too large: full {:.2}% vs cascade {:.2}% ({})",
            sweep.full_scan_accuracy_pct(),
            best.accuracy_pct,
            best.label
        );
        // honest accounting: the cascade points really sense fewer
        // strings, and the frontier is monotone by construction
        let mut frontier: Vec<&CascadePoint> =
            sweep.points.iter().filter(|p| p.frontier).collect();
        frontier.sort_by(|a, b| a.sensed_per_query.total_cmp(&b.sensed_per_query));
        for pair in frontier.windows(2) {
            assert!(
                pair[0].accuracy_pct <= pair[1].accuracy_pct,
                "frontier must be monotone"
            );
        }
        // rendering (text + CSV) covers every point of the same sweep
        let text = render(&sweep);
        assert!(text.contains("full AVSS scan"));
        assert!(text.contains("frontier"));
        let table = csv(&sweep);
        assert!(table.render().contains("sensed_per_query"));
    }
}
