//! Fig. 7: accuracy of SVSS vs AVSS, before and after (asymmetric) QAT.
//!
//! "Before QAT" evaluates the standard-trained controller under each
//! search mode; "after QAT" evaluates the controller meta-trained with the
//! matching quantization scheme (hat_svss / hat_avss — our HAT variants
//! subsume the modified-QAT of §3.2). The paper's claim: the SVSS→AVSS
//! accuracy gap shrinks to within ~1% after QAT.

use super::{run_mcam_eval, EpisodeSettings, RunResult};
use crate::device::variation::VariationModel;
use crate::encoding::Encoding;
use crate::fsl::store::ArtifactStore;
use crate::search::SearchMode;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Fig7Bar {
    pub mode: SearchMode,
    pub qat: bool,
    pub variant: &'static str,
    pub result: RunResult,
}

pub fn run(
    store: &ArtifactStore,
    dataset: &str,
    cl: usize,
    settings: EpisodeSettings,
) -> Result<Vec<Fig7Bar>> {
    let variation = VariationModel::nand_default();
    let cases: [(SearchMode, bool, &'static str); 4] = [
        (SearchMode::Svss, false, "std"),
        (SearchMode::Avss, false, "std"),
        (SearchMode::Svss, true, "hat_svss"),
        (SearchMode::Avss, true, "hat_avss"),
    ];
    let mut bars = Vec::new();
    for (mode, qat, variant) in cases {
        let result = run_mcam_eval(
            store,
            dataset,
            variant,
            Encoding::Mtmc,
            cl,
            mode,
            variation,
            settings,
        )?;
        bars.push(Fig7Bar { mode, qat, variant, result });
    }
    Ok(bars)
}

pub fn render(dataset: &str, bars: &[Fig7Bar]) -> String {
    let mut out = format!("Fig 7 ({dataset}): SVSS vs AVSS accuracy, before/after QAT\n");
    out.push_str("mode  qat    variant    accuracy%\n");
    for bar in bars {
        out.push_str(&format!(
            "{:<5} {:<6} {:<10} {}\n",
            bar.mode.name(),
            if bar.qat { "after" } else { "before" },
            bar.variant,
            super::pct(&bar.result.accuracy),
        ));
    }
    // the paper's headline: gap shrinks after QAT
    if bars.len() == 4 {
        let gap_before =
            bars[0].result.accuracy.accuracy_pct() - bars[1].result.accuracy.accuracy_pct();
        let gap_after =
            bars[2].result.accuracy.accuracy_pct() - bars[3].result.accuracy.accuracy_pct();
        out.push_str(&format!(
            "SVSS-AVSS gap: before QAT {gap_before:+.2}%, after QAT {gap_after:+.2}%\n"
        ));
    }
    out
}
