//! PJRT runtime: loads the AOT-compiled HLO artifacts (controllers, the
//! L1 Pallas kernel) and executes them on the request path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file`
//! reassigns ids and round-trips cleanly.

pub mod embed_service;

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::binio::Tensor;

/// Shared PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text module.
    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Load a controller executable with its batch geometry.
    pub fn load_controller(
        &self,
        path: &Path,
        batch: usize,
        image_hw: usize,
        embed_dim: usize,
    ) -> Result<Controller> {
        let exe = self.compile_hlo(path)?;
        Ok(Controller { exe, batch, image_hw, embed_dim })
    }

    /// Load the AOT Pallas MCAM-search kernel (fixed string count).
    pub fn load_mcam_kernel(&self, path: &Path, strings: usize) -> Result<McamKernel> {
        let exe = self.compile_hlo(path)?;
        Ok(McamKernel { exe, strings })
    }
}

/// A compiled controller: images → embeddings at a fixed batch size.
pub struct Controller {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub image_hw: usize,
    pub embed_dim: usize,
}

impl Controller {
    /// Embed exactly `batch` images (`batch * hw * hw` floats, NHWC with
    /// C=1). Returns `batch * embed_dim` floats.
    pub fn embed_batch(&self, images: &[f32]) -> Result<Vec<f32>> {
        let expect = self.batch * self.image_hw * self.image_hw;
        if images.len() != expect {
            bail!("embed_batch: got {} floats, want {}", images.len(), expect);
        }
        let input = xla::Literal::vec1(images).reshape(&[
            self.batch as i64,
            self.image_hw as i64,
            self.image_hw as i64,
            1,
        ])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.batch * self.embed_dim {
            bail!(
                "controller returned {} floats, want {}",
                values.len(),
                self.batch * self.embed_dim
            );
        }
        Ok(values)
    }

    /// Embed `n <= batch` images by padding the batch with zeros.
    pub fn embed_padded(&self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let per = self.image_hw * self.image_hw;
        if n * per != images.len() {
            bail!("embed_padded: {} floats for {} images", images.len(), n);
        }
        if n > self.batch {
            bail!("embed_padded: {} images exceed batch {}", n, self.batch);
        }
        let mut padded = vec![0f32; self.batch * per];
        padded[..images.len()].copy_from_slice(images);
        let mut out = self.embed_batch(&padded)?;
        out.truncate(n * self.embed_dim);
        Ok(out)
    }
}

/// The AOT-lowered L1 Pallas kernel: one MCAM search iteration over a
/// fixed-size string block. Used to cross-validate the native rust device
/// simulator against the exact kernel the HAT training differentiated
/// through.
pub struct McamKernel {
    exe: xla::PjRtLoadedExecutable,
    pub strings: usize,
}

impl McamKernel {
    /// `query`: 24 levels; `support`: `strings × 24` levels.
    /// Returns (currents f32, total mismatch i32, max mismatch i32).
    pub fn search(
        &self,
        query: &[i32],
        support: &[i32],
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<i32>)> {
        if query.len() != crate::CELLS_PER_STRING {
            bail!("query must have {} cells", crate::CELLS_PER_STRING);
        }
        if support.len() != self.strings * crate::CELLS_PER_STRING {
            bail!("support must be {} x {}", self.strings, crate::CELLS_PER_STRING);
        }
        let q = xla::Literal::vec1(query);
        let s = xla::Literal::vec1(support)
            .reshape(&[self.strings as i64, crate::CELLS_PER_STRING as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[q, s])?[0][0].to_literal_sync()?;
        let (current, total, max) = result.to_tuple3()?;
        Ok((
            current.to_vec::<f32>()?,
            total.to_vec::<i32>()?,
            max.to_vec::<i32>()?,
        ))
    }
}

/// Convenience: flatten an image tensor `(n, hw, hw)` into per-image
/// slices for the controller.
pub fn image_slice(images: &Tensor, index: usize) -> Result<&[f32]> {
    let dims = images.dims();
    if dims.len() != 3 {
        bail!("images tensor must be 3-D, got {:?}", dims);
    }
    let per = dims[1] * dims[2];
    let data = images.as_f32()?;
    Ok(&data[index * per..(index + 1) * per])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_slice_extracts() {
        let t = Tensor::F32 { dims: vec![2, 2, 2], data: (0..8).map(|i| i as f32).collect() };
        assert_eq!(image_slice(&t, 1).unwrap(), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn image_slice_rejects_2d() {
        let t = Tensor::F32 { dims: vec![4, 2], data: vec![0.0; 8] };
        assert!(image_slice(&t, 0).is_err());
    }

    // PJRT-dependent paths are exercised by rust/tests/test_runtime.rs
    // (integration), which skips when artifacts are absent.
}
