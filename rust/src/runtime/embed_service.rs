//! Embedding service: a dedicated thread that owns the PJRT controller
//! executable (the `xla` crate's handles are `!Send`/`!Sync`) and serves
//! batch-embed requests over channels. Worker threads hold a cheap,
//! clonable [`EmbedHandle`] — this is the leader-owns-PJRT topology of
//! the coordinator (DESIGN.md §3).

use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Reply = Result<Vec<f32>>;

struct EmbedRequest {
    flat_images: Vec<f32>,
    n: usize,
    reply: mpsc::Sender<Reply>,
}

/// Handle to the embedding service; clonable and `Send + Sync`.
#[derive(Clone)]
pub struct EmbedHandle {
    tx: Arc<Mutex<mpsc::Sender<EmbedRequest>>>,
}

impl EmbedHandle {
    /// Embed `n` images (flattened `n*hw*hw` floats); blocks until the
    /// service thread replies.
    pub fn embed(&self, flat_images: &[f32], n: usize) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(EmbedRequest { flat_images: flat_images.to_vec(), n, reply: reply_tx })
            .map_err(|_| anyhow!("embed service stopped"))?;
        reply_rx.recv().map_err(|_| anyhow!("embed service dropped reply"))?
    }

    /// Adapt into the coordinator's [`crate::coordinator::worker::EmbedFn`].
    pub fn as_embed_fn(&self) -> crate::coordinator::worker::EmbedFn {
        let handle = self.clone();
        Arc::new(move |flat: &[f32], n: usize| handle.embed(flat, n))
    }
}

/// The service: owns the thread; dropping it stops the service once all
/// handles are gone.
pub struct EmbedService {
    handle: EmbedHandle,
    _thread: JoinHandle<()>,
}

impl EmbedService {
    /// Spawn the service. The PJRT client + controller are constructed
    /// *inside* the thread (they are `!Send`).
    pub fn spawn(
        hlo_path: PathBuf,
        batch: usize,
        image_hw: usize,
        embed_dim: usize,
    ) -> Result<EmbedService> {
        let (tx, rx) = mpsc::channel::<EmbedRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("mcamvss-embed".into())
            .spawn(move || {
                let controller = (|| -> Result<super::Controller> {
                    let runtime = super::Runtime::cpu()?;
                    runtime.load_controller(&hlo_path, batch, image_hw, embed_dim)
                })();
                match controller {
                    Ok(controller) => {
                        let _ = ready_tx.send(Ok(()));
                        while let Ok(req) = rx.recv() {
                            let result = controller.embed_padded(&req.flat_images, req.n);
                            let _ = req.reply.send(result);
                        }
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })
            .context("spawn embed service")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("embed service died during startup"))??;
        Ok(EmbedService {
            handle: EmbedHandle { tx: Arc::new(Mutex::new(tx)) },
            _thread: thread,
        })
    }

    pub fn handle(&self) -> EmbedHandle {
        self.handle.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_failure_is_reported() {
        let err = EmbedService::spawn(PathBuf::from("/nonexistent.hlo.txt"), 1, 4, 4);
        assert!(err.is_err());
    }

    // Success paths are exercised by rust/tests/test_e2e.rs and the
    // e2e_fsl_pipeline example (artifact-dependent).
}
