//! Hand-rolled CLI argument parser (no clap in the offline image):
//! `binary <command> [--key value]... [--flag]...`.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Option keys that take a value (everything else after `--` is a flag).
const VALUE_KEYS: &[&str] = &[
    "config", "dataset", "variant", "encoding", "cl", "mode", "n-way", "k-shot",
    "n-query", "episodes", "workers", "shards", "requests", "seed", "out",
    "artifacts", "filter", "batch", "top-k", "backend", "metric", "steps",
    "meta-episodes", "cascade-columns", "cascade-ladder", "cascade-shortlist",
    "cascade-margin", "cascade-budget", "listen", "connect", "clients",
    "addr-file", "serve-seconds", "max-connections", "max-in-flight",
    "idle-timeout-ms", "dims", "stuck-low", "stuck-high", "retention-drift",
    "read-disturb", "scrub-canaries", "scrub-spares", "scrub-margin",
    "scrub-every", "routing-probes", "routing-fraction", "routing-min-coverage",
    "routing-refresh",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if VALUE_KEYS.contains(&key) {
                    let Some(value) = iter.next() else {
                        bail!("option --{key} requires a value");
                    };
                    args.options.insert(key.to_string(), value.clone());
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(arg.clone());
            } else {
                args.positional.push(arg.clone());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.opt(key) {
            None => Ok(None),
            Some(raw) => match raw.parse() {
                Ok(v) => Ok(Some(v)),
                Err(_) => bail!("--{key}: expected integer, got {raw:?}"),
            },
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(&parts.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let args = parse(&["eval", "--dataset", "cub", "--cl", "8", "--ideal", "x"]);
        assert_eq!(args.command.as_deref(), Some("eval"));
        assert_eq!(args.opt("dataset"), Some("cub"));
        assert_eq!(args.opt_usize("cl").unwrap(), Some(8));
        assert!(args.flag("ideal"));
        assert!(!args.flag("other"));
        assert_eq!(args.positional(), &["x".to_string()]);
    }

    #[test]
    fn missing_value_errors() {
        let argv: Vec<String> = vec!["eval".into(), "--dataset".into()];
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn bad_int_errors() {
        let args = parse(&["eval", "--cl", "abc"]);
        assert!(args.opt_usize("cl").is_err());
    }

    #[test]
    fn serving_keys_take_values() {
        let args = parse(&["serve", "--top-k", "5", "--backend", "float", "--metric", "l2"]);
        assert_eq!(args.opt_usize("top-k").unwrap(), Some(5));
        assert_eq!(args.opt("backend"), Some("float"));
        assert_eq!(args.opt("metric"), Some("l2"));
    }

    #[test]
    fn cascade_keys_take_values() {
        let args = parse(&[
            "serve", "--cascade", "--cascade-columns", "2", "--cascade-shortlist", "64",
            "--cascade-margin", "6.5", "--cascade-budget", "40",
        ]);
        assert!(args.flag("cascade"));
        assert_eq!(args.opt_usize("cascade-columns").unwrap(), Some(2));
        assert_eq!(args.opt_usize("cascade-shortlist").unwrap(), Some(64));
        assert_eq!(args.opt("cascade-margin"), Some("6.5"));
        assert_eq!(args.opt_usize("cascade-budget").unwrap(), Some(40));
    }

    #[test]
    fn network_keys_take_values() {
        let args = parse(&[
            "serve", "--listen", "127.0.0.1:0", "--max-connections", "8",
            "--max-in-flight", "4", "--idle-timeout-ms", "500", "--addr-file",
            "/tmp/addr", "--serve-seconds", "30", "--synthetic",
        ]);
        assert_eq!(args.opt("listen"), Some("127.0.0.1:0"));
        assert_eq!(args.opt_usize("max-connections").unwrap(), Some(8));
        assert_eq!(args.opt_usize("max-in-flight").unwrap(), Some(4));
        assert_eq!(args.opt_usize("idle-timeout-ms").unwrap(), Some(500));
        assert_eq!(args.opt("addr-file"), Some("/tmp/addr"));
        assert_eq!(args.opt_usize("serve-seconds").unwrap(), Some(30));
        assert!(args.flag("synthetic"));

        let args = parse(&[
            "bench-client", "--connect", "127.0.0.1:7171", "--clients", "4",
            "--requests", "100", "--dims", "48", "--shutdown-server",
        ]);
        assert_eq!(args.command.as_deref(), Some("bench-client"));
        assert_eq!(args.opt("connect"), Some("127.0.0.1:7171"));
        assert_eq!(args.opt_usize("clients").unwrap(), Some(4));
        assert_eq!(args.opt_usize("dims").unwrap(), Some(48));
        assert!(args.flag("shutdown-server"));
    }

    #[test]
    fn fault_and_scrub_keys_take_values() {
        let args = parse(&[
            "serve", "--faults", "--stuck-low", "0.01", "--stuck-high", "0.002",
            "--retention-drift", "0.02", "--read-disturb", "0.001", "--scrub",
            "--scrub-canaries", "8", "--scrub-spares", "3", "--scrub-margin",
            "0.85", "--scrub-every", "16",
        ]);
        assert!(args.flag("faults"));
        assert!(args.flag("scrub"));
        assert_eq!(args.opt("stuck-low"), Some("0.01"));
        assert_eq!(args.opt("stuck-high"), Some("0.002"));
        assert_eq!(args.opt("retention-drift"), Some("0.02"));
        assert_eq!(args.opt("read-disturb"), Some("0.001"));
        assert_eq!(args.opt_usize("scrub-canaries").unwrap(), Some(8));
        assert_eq!(args.opt_usize("scrub-spares").unwrap(), Some(3));
        assert_eq!(args.opt("scrub-margin"), Some("0.85"));
        assert_eq!(args.opt_usize("scrub-every").unwrap(), Some(16));
    }

    #[test]
    fn routing_keys_take_values() {
        let args = parse(&[
            "serve", "--routing", "--routing-probes", "4", "--routing-fraction",
            "0.25", "--routing-min-coverage", "0.5", "--routing-refresh", "eager",
        ]);
        assert!(args.flag("routing"));
        assert_eq!(args.opt_usize("routing-probes").unwrap(), Some(4));
        assert_eq!(args.opt("routing-fraction"), Some("0.25"));
        assert_eq!(args.opt("routing-min-coverage"), Some("0.5"));
        assert_eq!(args.opt("routing-refresh"), Some("eager"));
    }

    #[test]
    fn training_keys_take_values() {
        let args = parse(&["train", "--steps", "12", "--meta-episodes", "3", "--smoke"]);
        assert_eq!(args.command.as_deref(), Some("train"));
        assert_eq!(args.opt_usize("steps").unwrap(), Some(12));
        assert_eq!(args.opt_usize("meta-episodes").unwrap(), Some(3));
        assert!(args.flag("smoke"));
    }
}
