//! Few-shot-learning harness: embedding datasets exported by the AOT
//! pipeline, N-way K-shot episode sampling, and episode evaluation
//! against any [`VectorSearchBackend`] (the MCAM
//! [`crate::search::engine::SearchEngine`], the float
//! [`crate::baselines::FloatBaseline`], ...).

pub mod store;

use crate::search::api::{EngineError, SearchRequest, SupportSet, VectorSearchBackend};
use crate::testutil::{derive_seed, Rng};
use std::collections::BTreeMap;

/// Stream salt separating the episode sampler from every other consumer
/// of a run's seed (engine shards, coordinator replicas, HAT noise).
pub const EPISODE_STREAM: u64 = 0xE915_0DE5;

/// The one seed-derivation scheme for episode sampling, shared by
/// training ([`crate::hat`]) and evaluation ([`crate::experiments`],
/// the `serve` CLI): episode `t` of run seed `s` draws from
/// `derive_seed(derive_seed(s, EPISODE_STREAM), t)`.
///
/// Two properties follow (pinned by `rust/tests/test_determinism.rs`):
/// the stream is independent of engine/backend RNG consumption (shard
/// counts, backend choice, device noise never shift it), and episode `t`
/// can be regenerated without replaying episodes `0..t`.
pub fn episode_rng(seed: u64, episode: u64) -> Rng {
    Rng::new(derive_seed(derive_seed(seed, EPISODE_STREAM), episode))
}

/// A set of embeddings with global class labels, class-indexed.
#[derive(Debug, Clone)]
pub struct EmbeddingDataset {
    pub dims: usize,
    /// Row-major `n × dims`.
    data: Vec<f32>,
    labels: Vec<u32>,
    /// class label → row indices.
    by_class: BTreeMap<u32, Vec<usize>>,
}

impl EmbeddingDataset {
    pub fn new(dims: usize, data: Vec<f32>, labels: Vec<u32>) -> EmbeddingDataset {
        assert!(dims > 0);
        assert_eq!(data.len(), labels.len() * dims, "data/label size mismatch");
        let mut by_class: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        for (row, &label) in labels.iter().enumerate() {
            by_class.entry(label).or_default().push(row);
        }
        EmbeddingDataset { dims, data, labels, by_class }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn embedding(&self, row: usize) -> &[f32] {
        &self.data[row * self.dims..(row + 1) * self.dims]
    }

    pub fn label(&self, row: usize) -> u32 {
        self.labels[row]
    }

    pub fn classes(&self) -> Vec<u32> {
        self.by_class.keys().copied().collect()
    }

    pub fn n_classes(&self) -> usize {
        self.by_class.len()
    }

    pub fn class_rows(&self, class: u32) -> &[usize] {
        self.by_class.get(&class).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// One N-way K-shot episode with episode-local labels.
#[derive(Debug, Clone)]
pub struct Episode {
    pub n_way: usize,
    pub k_shot: usize,
    /// (dataset row, local label) for each support vector.
    pub support: Vec<(usize, u32)>,
    /// (dataset row, local label) for each query.
    pub queries: Vec<(usize, u32)>,
}

/// Sample an episode: `n_way` distinct classes, `k_shot` support +
/// `n_query` query samples per class (disjoint).
pub fn sample_episode(
    ds: &EmbeddingDataset,
    rng: &mut Rng,
    n_way: usize,
    k_shot: usize,
    n_query: usize,
) -> Episode {
    let classes = ds.classes();
    assert!(
        n_way <= classes.len(),
        "{n_way}-way episode but only {} classes",
        classes.len()
    );
    let chosen = rng.choose_distinct(classes.len(), n_way);
    let mut support = Vec::with_capacity(n_way * k_shot);
    let mut queries = Vec::with_capacity(n_way * n_query);
    for (local, &ci) in chosen.iter().enumerate() {
        let rows = ds.class_rows(classes[ci]);
        assert!(
            rows.len() >= k_shot + n_query,
            "class {} has only {} samples",
            classes[ci],
            rows.len()
        );
        let picks = rng.choose_distinct(rows.len(), k_shot + n_query);
        for &p in &picks[..k_shot] {
            support.push((rows[p], local as u32));
        }
        for &p in &picks[k_shot..] {
            queries.push((rows[p], local as u32));
        }
    }
    Episode { n_way, k_shot, support, queries }
}

/// Program an episode's support set into any backend and classify its
/// queries. Returns `(correct, total)`.
pub fn evaluate_episode<B: VectorSearchBackend>(
    backend: &mut B,
    ds: &EmbeddingDataset,
    episode: &Episode,
) -> Result<(usize, usize), EngineError> {
    let embs: Vec<&[f32]> = episode.support.iter().map(|&(row, _)| ds.embedding(row)).collect();
    let labels: Vec<u32> = episode.support.iter().map(|&(_, l)| l).collect();
    let support = SupportSet::from_refs(ds.dims, &embs, &labels)?;
    backend.program(&support)?;
    let mut correct = 0;
    for &(row, truth) in &episode.queries {
        let response = backend.search(&SearchRequest::new(ds.embedding(row)))?;
        if response.top().map(|h| h.label) == Some(truth) {
            correct += 1;
        }
    }
    Ok((correct, episode.queries.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Encoding;
    use crate::search::engine::{EngineConfig, SearchEngine};
    use crate::search::SearchMode;

    fn toy_dataset(n_classes: usize, per_class: usize, dims: usize) -> EmbeddingDataset {
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for c in 0..n_classes {
            let proto: Vec<f64> = (0..dims).map(|_| rng.range_f64(0.3, 2.7)).collect();
            for _ in 0..per_class {
                data.extend(proto.iter().map(|&p| (p + 0.03 * rng.gaussian()).max(0.0) as f32));
                labels.push(c as u32);
            }
        }
        EmbeddingDataset::new(dims, data, labels)
    }

    #[test]
    fn dataset_indexing() {
        let ds = toy_dataset(5, 4, 8);
        assert_eq!(ds.len(), 20);
        assert_eq!(ds.n_classes(), 5);
        assert_eq!(ds.class_rows(2).len(), 4);
        assert_eq!(ds.label(4), 1);
        assert_eq!(ds.embedding(0).len(), 8);
        assert!(ds.class_rows(99).is_empty());
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn bad_sizes_panic() {
        EmbeddingDataset::new(4, vec![0.0; 7], vec![0, 1]);
    }

    #[test]
    fn episode_structure() {
        let ds = toy_dataset(10, 6, 8);
        let mut rng = Rng::new(2);
        let ep = sample_episode(&ds, &mut rng, 4, 2, 3);
        assert_eq!(ep.support.len(), 8);
        assert_eq!(ep.queries.len(), 12);
        // local labels cover 0..n_way
        let mut locals: Vec<u32> = ep.support.iter().map(|&(_, l)| l).collect();
        locals.sort_unstable();
        locals.dedup();
        assert_eq!(locals, vec![0, 1, 2, 3]);
        // support and query rows are disjoint
        for &(qrow, _) in &ep.queries {
            assert!(ep.support.iter().all(|&(srow, _)| srow != qrow));
        }
        // support/query of the same local label share the global class
        for &(srow, sl) in &ep.support {
            for &(qrow, ql) in &ep.queries {
                if sl == ql {
                    assert_eq!(ds.label(srow), ds.label(qrow));
                }
            }
        }
    }

    #[test]
    fn evaluate_clustered_episode() {
        let ds = toy_dataset(12, 8, 48);
        let mut rng = Rng::new(3);
        let ep = sample_episode(&ds, &mut rng, 10, 3, 4);
        let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0).ideal();
        let mut engine = SearchEngine::new(cfg, 48, ep.support.len()).unwrap();
        let (correct, total) = evaluate_episode(&mut engine, &ds, &ep).unwrap();
        assert_eq!(total, 40);
        assert!(correct as f64 / total as f64 > 0.9, "{correct}/{total}");
    }

    #[test]
    fn evaluate_episode_is_backend_generic() {
        // The same episode harness drives the exact-float backend.
        let ds = toy_dataset(6, 6, 16);
        let mut rng = Rng::new(5);
        let ep = sample_episode(&ds, &mut rng, 5, 2, 3);
        let mut backend =
            crate::baselines::FloatBaseline::new(16, crate::baselines::Metric::L1).unwrap();
        let (correct, total) = evaluate_episode(&mut backend, &ds, &ep).unwrap();
        assert_eq!(total, 15);
        assert!(correct as f64 / total as f64 > 0.9, "{correct}/{total}");
    }

    #[test]
    #[should_panic(expected = "-way episode")]
    fn too_many_ways_panics() {
        let ds = toy_dataset(3, 4, 8);
        let mut rng = Rng::new(4);
        sample_episode(&ds, &mut rng, 5, 1, 1);
    }
}
