//! Artifact store: typed access to the `artifacts/` tree produced by
//! `python/compile/aot.py` (embeddings, labels, raw images, clip
//! calibrations, controller HLO paths).

use super::EmbeddingDataset;
use crate::util::binio::{read_tensor, Tensor};
use crate::util::manifest::Manifest;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Controller training variants exported by the AOT pipeline.
pub const VARIANTS: [&str; 3] = ["std", "hat_svss", "hat_avss"];

/// Dataset names exported by the AOT pipeline.
pub const DATASETS: [&str; 2] = ["omniglot", "cub"];

#[derive(Debug, Clone)]
pub struct ArtifactStore {
    root: PathBuf,
    manifest: Manifest,
}

impl ArtifactStore {
    pub fn open(root: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(&root.join("manifest.txt"))
            .with_context(|| format!("artifact tree at {} incomplete", root.display()))?;
        Ok(ArtifactStore { root: root.to_path_buf(), manifest })
    }

    /// Open the default location (`MCAMVSS_ARTIFACTS` or `artifacts/`).
    pub fn open_default() -> Result<ArtifactStore> {
        Self::open(&crate::util::artifacts_dir())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Quantizer clip point calibrated for (dataset, variant).
    pub fn clip(&self, dataset: &str, variant: &str) -> Result<f64> {
        self.manifest.get_f64(&format!("clip_{dataset}_{variant}"))
    }

    pub fn embed_dim(&self, dataset: &str) -> Result<usize> {
        self.manifest.get_usize(&format!("embed_dim_{dataset}"))
    }

    pub fn image_hw(&self, dataset: &str) -> Result<usize> {
        self.manifest.get_usize(&format!("image_hw_{dataset}"))
    }

    /// Load the embeddings of (dataset, variant, split) as an
    /// [`EmbeddingDataset`].
    pub fn embeddings(&self, dataset: &str, variant: &str, split: &str) -> Result<EmbeddingDataset> {
        let emb_path = self.root.join("data").join(format!("emb_{dataset}_{variant}_{split}.mvt"));
        let lab_path = self.root.join("data").join(format!("labels_{dataset}_{split}.mvt"));
        let emb = read_tensor(&emb_path)?;
        let labels = read_tensor(&lab_path)?;
        let dims = match emb.dims() {
            [_, d] => *d,
            other => bail!("embeddings must be 2-D, got {:?}", other),
        };
        let data = emb.as_f32()?.to_vec();
        let labels: Vec<u32> = labels.as_i32()?.iter().map(|&l| l as u32).collect();
        Ok(EmbeddingDataset::new(dims, data, labels))
    }

    /// Raw test-split images `(n, hw, hw)` for the end-to-end path.
    pub fn test_images(&self, dataset: &str) -> Result<Tensor> {
        read_tensor(&self.root.join("data").join(format!("images_{dataset}_test.mvt")))
    }

    /// Test-split labels (global class ids).
    pub fn test_labels(&self, dataset: &str) -> Result<Vec<u32>> {
        let t = read_tensor(&self.root.join("data").join(format!("labels_{dataset}_test.mvt")))?;
        Ok(t.as_i32()?.iter().map(|&l| l as u32).collect())
    }

    /// Path to the AOT-compiled controller HLO for (dataset, variant) at
    /// a given batch size.
    pub fn controller_hlo(&self, dataset: &str, variant: &str, batch: usize) -> PathBuf {
        self.root
            .join("hlo")
            .join(format!("controller_{dataset}_{variant}_b{batch}.hlo.txt"))
    }

    /// Path to the AOT-compiled L1 Pallas kernel HLO.
    pub fn kernel_hlo(&self, strings: usize) -> PathBuf {
        self.root.join("hlo").join(format!("mcam_search_{strings}.hlo.txt"))
    }

    /// Path to a cross-layer test vector.
    pub fn testvec(&self, name: &str) -> PathBuf {
        self.root.join("testvec").join(format!("{name}.mvt"))
    }
}

/// Incremental writer for an [`ArtifactStore`]-compatible tree: tensors
/// under the root, `key = value` manifest entries merged with any
/// manifest already present (so per-variant exports accumulate —
/// [`crate::hat::export_artifacts`] calls this once per trained
/// variant).
#[derive(Debug)]
pub struct ArtifactWriter {
    root: PathBuf,
    entries: std::collections::BTreeMap<String, String>,
}

impl ArtifactWriter {
    /// Open `root` for writing, loading existing manifest entries if
    /// the tree already exists.
    pub fn open(root: &Path) -> Result<ArtifactWriter> {
        std::fs::create_dir_all(root.join("data"))
            .with_context(|| format!("create artifact tree at {}", root.display()))?;
        let mut entries = std::collections::BTreeMap::new();
        let manifest_path = root.join("manifest.txt");
        if manifest_path.exists() {
            let manifest = Manifest::load(&manifest_path)?;
            for (k, v) in manifest.iter() {
                entries.insert(k.to_string(), v.to_string());
            }
        }
        Ok(ArtifactWriter { root: root.to_path_buf(), entries })
    }

    /// Stage a manifest entry (written by [`Self::finish`]).
    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    /// Write a tensor at a root-relative path (parents created).
    pub fn write_tensor(&self, rel_path: &str, tensor: &Tensor) -> Result<()> {
        let path = self.root.join(rel_path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::util::binio::write_tensor(&path, tensor)
    }

    /// Write the merged manifest and reopen the tree as a store.
    pub fn finish(self) -> Result<ArtifactStore> {
        let mut text = String::new();
        for (k, v) in &self.entries {
            text.push_str(&format!("{k} = {v}\n"));
        }
        std::fs::write(self.root.join("manifest.txt"), text)
            .with_context(|| format!("write manifest at {}", self.root.display()))?;
        ArtifactStore::open(&self.root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_fails() {
        assert!(ArtifactStore::open(Path::new("/nonexistent/path")).is_err());
    }

    #[test]
    fn writer_roundtrips_and_merges() {
        let root = std::env::temp_dir().join(format!("mvt_store_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let mut w = ArtifactWriter::open(&root).unwrap();
        w.set("clip_synth_std", "2.5");
        w.set("embed_dim_synth", "16");
        w.write_tensor(
            "data/emb_synth_std_test.mvt",
            &Tensor::F32 { dims: vec![2, 16], data: vec![0.25; 32] },
        )
        .unwrap();
        w.write_tensor(
            "data/labels_synth_test.mvt",
            &Tensor::I32 { dims: vec![2], data: vec![0, 1] },
        )
        .unwrap();
        let store = w.finish().unwrap();
        assert_eq!(store.clip("synth", "std").unwrap(), 2.5);
        let ds = store.embeddings("synth", "std", "test").unwrap();
        assert_eq!((ds.len(), ds.dims), (2, 16));

        // reopening merges instead of clobbering
        let mut w2 = ArtifactWriter::open(&root).unwrap();
        w2.set("clip_synth_hat_avss", "3.5");
        let store = w2.finish().unwrap();
        assert_eq!(store.clip("synth", "std").unwrap(), 2.5);
        assert_eq!(store.clip("synth", "hat_avss").unwrap(), 3.5);
        std::fs::remove_dir_all(&root).ok();
    }

    // Artifact-dependent behaviour is covered by the integration tests in
    // rust/tests/, which skip gracefully when artifacts are absent.
    #[test]
    fn paths_are_deterministic() {
        if let Ok(store) = ArtifactStore::open_default() {
            let p = store.controller_hlo("omniglot", "std", 8);
            assert!(p.to_string_lossy().ends_with("controller_omniglot_std_b8.hlo.txt"));
            let k = store.kernel_hlo(4096);
            assert!(k.to_string_lossy().ends_with("mcam_search_4096.hlo.txt"));
        }
    }
}
