//! Artifact store: typed access to the `artifacts/` tree produced by
//! `python/compile/aot.py` (embeddings, labels, raw images, clip
//! calibrations, controller HLO paths).

use super::EmbeddingDataset;
use crate::util::binio::{read_tensor, Tensor};
use crate::util::manifest::Manifest;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Controller training variants exported by the AOT pipeline.
pub const VARIANTS: [&str; 3] = ["std", "hat_svss", "hat_avss"];

/// Dataset names exported by the AOT pipeline.
pub const DATASETS: [&str; 2] = ["omniglot", "cub"];

#[derive(Debug, Clone)]
pub struct ArtifactStore {
    root: PathBuf,
    manifest: Manifest,
}

impl ArtifactStore {
    pub fn open(root: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(&root.join("manifest.txt"))
            .with_context(|| format!("artifact tree at {} incomplete", root.display()))?;
        Ok(ArtifactStore { root: root.to_path_buf(), manifest })
    }

    /// Open the default location (`MCAMVSS_ARTIFACTS` or `artifacts/`).
    pub fn open_default() -> Result<ArtifactStore> {
        Self::open(&crate::util::artifacts_dir())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Quantizer clip point calibrated for (dataset, variant).
    pub fn clip(&self, dataset: &str, variant: &str) -> Result<f64> {
        self.manifest.get_f64(&format!("clip_{dataset}_{variant}"))
    }

    pub fn embed_dim(&self, dataset: &str) -> Result<usize> {
        self.manifest.get_usize(&format!("embed_dim_{dataset}"))
    }

    pub fn image_hw(&self, dataset: &str) -> Result<usize> {
        self.manifest.get_usize(&format!("image_hw_{dataset}"))
    }

    /// Load the embeddings of (dataset, variant, split) as an
    /// [`EmbeddingDataset`].
    pub fn embeddings(&self, dataset: &str, variant: &str, split: &str) -> Result<EmbeddingDataset> {
        let emb_path = self.root.join("data").join(format!("emb_{dataset}_{variant}_{split}.mvt"));
        let lab_path = self.root.join("data").join(format!("labels_{dataset}_{split}.mvt"));
        let emb = read_tensor(&emb_path)?;
        let labels = read_tensor(&lab_path)?;
        let dims = match emb.dims() {
            [_, d] => *d,
            other => bail!("embeddings must be 2-D, got {:?}", other),
        };
        let data = emb.as_f32()?.to_vec();
        let labels: Vec<u32> = labels.as_i32()?.iter().map(|&l| l as u32).collect();
        Ok(EmbeddingDataset::new(dims, data, labels))
    }

    /// Raw test-split images `(n, hw, hw)` for the end-to-end path.
    pub fn test_images(&self, dataset: &str) -> Result<Tensor> {
        read_tensor(&self.root.join("data").join(format!("images_{dataset}_test.mvt")))
    }

    /// Test-split labels (global class ids).
    pub fn test_labels(&self, dataset: &str) -> Result<Vec<u32>> {
        let t = read_tensor(&self.root.join("data").join(format!("labels_{dataset}_test.mvt")))?;
        Ok(t.as_i32()?.iter().map(|&l| l as u32).collect())
    }

    /// Path to the AOT-compiled controller HLO for (dataset, variant) at
    /// a given batch size.
    pub fn controller_hlo(&self, dataset: &str, variant: &str, batch: usize) -> PathBuf {
        self.root
            .join("hlo")
            .join(format!("controller_{dataset}_{variant}_b{batch}.hlo.txt"))
    }

    /// Path to the AOT-compiled L1 Pallas kernel HLO.
    pub fn kernel_hlo(&self, strings: usize) -> PathBuf {
        self.root.join("hlo").join(format!("mcam_search_{strings}.hlo.txt"))
    }

    /// Path to a cross-layer test vector.
    pub fn testvec(&self, name: &str) -> PathBuf {
        self.root.join("testvec").join(format!("{name}.mvt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_fails() {
        assert!(ArtifactStore::open(Path::new("/nonexistent/path")).is_err());
    }

    // Artifact-dependent behaviour is covered by the integration tests in
    // rust/tests/, which skip gracefully when artifacts are absent.
    #[test]
    fn paths_are_deterministic() {
        if let Ok(store) = ArtifactStore::open_default() {
            let p = store.controller_hlo("omniglot", "std", 8);
            assert!(p.to_string_lossy().ends_with("controller_omniglot_std_b8.hlo.txt"));
            let k = store.kernel_hlo(4096);
            assert!(k.to_string_lossy().ends_with("mcam_search_4096.hlo.txt"));
        }
    }
}
