//! A NAND-flash MCAM block: up to 128K strings × 24 MLC unit cells.
//!
//! Supports the two operations of the IMAS system [14]:
//!
//! * **program** — write 24 cell levels into a string (with program-time
//!   variation sampled per cell), and
//! * **search** — drive 24 word-line levels and read the resulting
//!   series-conductance current of selected strings.
//!
//! The sense path is the crate's performance-critical kernel (~3M cell
//! evaluations per engine iteration at full block occupancy). Cells are
//! stored **cell-major** (structure-of-arrays: one plane per word line,
//! strings contiguous within a plane) and sensed by the fused, tiled
//! sense→vote→accumulate kernel [`McamBlock::sense_votes_range`]; the
//! scalar walk is retained as [`McamBlock::sense_votes_range_naive`],
//! the reference oracle for the kernel-equivalence tests and the
//! `perf_kernel` microbench. See DESIGN.md §Perf for the optimization
//! log.

use super::faults::FaultModel;
use super::sense::{SenseLadder, SeriesRungs};
use super::variation::VariationModel;
use super::McamParams;
use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// Strings per tile of the fused sense kernel: the f32 accumulator tile
/// (256 B) stays register/L1-resident while the 24 cell planes stream
/// through it, and 64 independent per-string sums give the pipeline
/// enough ILP to hide the dependent-add latency the scalar walk
/// serializes on.
const SENSE_TILE: usize = 64;

/// One MCAM block.
pub struct McamBlock {
    params: McamParams,
    variation: VariationModel,
    faults: FaultModel,
    capacity: usize,
    /// Programmed cell levels, cell-major (structure-of-arrays): plane
    /// `l` stores cell `l` of every string contiguously, at
    /// `levels[l * capacity + idx]`, so the sense kernel's string loop
    /// streams sequential memory (see DESIGN.md §Perf).
    levels: Vec<u8>,
    /// Program-time per-cell resistance variation factor, same cell-major
    /// plane layout. (Kept separate from the levels instead of expanding
    /// per-drive resistances: 120 B/string of traffic instead of 384 B —
    /// see DESIGN.md §Perf.)
    var: Vec<f32>,
    /// 4x4 match-resistance lookup `lut[q][s]` (L1-resident).
    lut: [[f32; 4]; 4],
    /// Thresholds the cached series-domain `rungs` were computed for.
    /// The ideal fused path votes in the series-resistance domain;
    /// rebuilding the exact rungs costs ~31 f64 divisions per threshold,
    /// so they are cached across calls and invalidated by exact
    /// threshold comparison.
    rung_thresholds: Vec<f64>,
    rungs: SeriesRungs,
    /// Per-tile vote scratch for the noisy fused path (reused across
    /// calls so the hot path never allocates).
    votes_scratch: Vec<u32>,
    programmed: usize,
    rng: Rng,
}

impl McamBlock {
    pub fn new(
        capacity: usize,
        params: McamParams,
        variation: VariationModel,
        seed: u64,
    ) -> McamBlock {
        McamBlock {
            lut: params.resistance_lut(),
            params,
            variation,
            faults: FaultModel::NONE,
            capacity,
            levels: vec![0; capacity * CELLS_PER_STRING],
            var: vec![1.0; capacity * CELLS_PER_STRING],
            rung_thresholds: Vec::new(),
            rungs: SeriesRungs::default(),
            votes_scratch: Vec::new(),
            programmed: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn programmed(&self) -> usize {
        self.programmed
    }

    pub fn params(&self) -> &McamParams {
        &self.params
    }

    pub fn variation(&self) -> &VariationModel {
        &self.variation
    }

    /// Erase the block (programmed count returns to zero; variation is
    /// resampled on the next program, modeling a program/erase cycle).
    pub fn erase(&mut self) {
        self.programmed = 0;
    }

    /// Set the fault-injection model applied to subsequently programmed
    /// strings (reliability ablations).
    pub fn set_faults(&mut self, faults: FaultModel) {
        self.faults = faults;
    }

    /// Program the next free string with `cells` levels. Returns the
    /// string index.
    pub fn program_string(&mut self, cells: &[u8; CELLS_PER_STRING]) -> usize {
        assert!(
            self.programmed < self.capacity,
            "MCAM block full ({} strings)",
            self.capacity
        );
        let mut cells = *cells;
        if !self.faults.is_none() {
            self.faults.corrupt_string(&mut cells, &mut self.rng);
        }
        let idx = self.programmed;
        // Scatter across the cell planes; the per-cell RNG draw order
        // (l = 0..23) matches the string-major layout this replaced, so
        // seeded replays stay bit-identical.
        for (l, &s) in cells.iter().enumerate() {
            assert!(s <= 3, "cell level {s} out of range");
            let cell = l * self.capacity + idx;
            self.levels[cell] = s;
            self.var[cell] = self.variation.cell_factor(&mut self.rng);
        }
        self.programmed += 1;
        idx
    }

    /// Overwrite the cell *levels* of an already-programmed string in
    /// place, leaving its variation factors untouched and consuming **no**
    /// RNG draws. This is the fault-overlay / scrub-rewrite hook
    /// (DESIGN.md §Reliability): the engine computes the corrupted (or
    /// healed) levels through the pure-hash
    /// [`crate::device::faults::FaultState`] and materializes them here,
    /// so applying or clearing faults never perturbs the seeded
    /// program-variation or read-noise streams.
    pub fn rewrite_cells(&mut self, idx: usize, cells: &[u8; CELLS_PER_STRING]) {
        assert!(idx < self.programmed, "rewrite of unprogrammed string {idx}");
        for (l, &s) in cells.iter().enumerate() {
            assert!(s <= 3, "cell level {s} out of range");
            self.levels[l * self.capacity + idx] = s;
        }
    }

    /// Programmed levels of string `idx`, gathered across the cell
    /// planes (test/debug).
    pub fn string_levels(&self, idx: usize) -> [u8; CELLS_PER_STRING] {
        let mut cells = [0u8; CELLS_PER_STRING];
        for (l, cell) in cells.iter_mut().enumerate() {
            *cell = self.levels[l * self.capacity + idx];
        }
        cells
    }

    /// Ideal (noise-free) current of string `idx` under `wordline` — the
    /// scalar reference path (per-string plane gather, double-indexed
    /// LUT). The fused kernel reproduces its f32 cell-sum order
    /// (l = 0..23) bit-for-bit.
    #[inline]
    pub fn string_current_ideal(&self, idx: usize, wordline: &[u8; CELLS_PER_STRING]) -> f64 {
        let mut series = 0f32;
        for (l, &q) in wordline.iter().enumerate() {
            debug_assert!(q <= 3);
            let cell = l * self.capacity + idx;
            series += self.lut[q as usize][self.levels[cell] as usize] * self.var[cell];
        }
        self.params.v_bl / series as f64
    }

    /// Hoist the word-line gather: for a fixed drive, cell `l` always
    /// selects LUT row `lut[wordline[l]]`, so the 24×4 row table is
    /// built once per sense call instead of double-indexing the LUT per
    /// cell per string.
    #[inline]
    fn wordline_rows(&self, wordline: &[u8; CELLS_PER_STRING]) -> [[f32; 4]; CELLS_PER_STRING] {
        let mut rows = [[0f32; 4]; CELLS_PER_STRING];
        for (row, &q) in rows.iter_mut().zip(wordline) {
            debug_assert!(q <= 3);
            *row = self.lut[q as usize];
        }
        rows
    }

    /// Series-resistance sums of `tile` strings starting at `base`,
    /// streamed plane by plane with the hoisted word-line rows. The
    /// per-string accumulation order is l = 0..23 exactly as in
    /// [`Self::string_current_ideal`], so the f32 sums are bit-identical
    /// to the scalar reference.
    #[inline]
    fn tile_series(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        base: usize,
        tile: usize,
        acc: &mut [f32; SENSE_TILE],
    ) {
        acc[..tile].fill(0.0);
        for (l, row) in rows.iter().enumerate() {
            let plane = l * self.capacity + base;
            let lv = &self.levels[plane..plane + tile];
            let vr = &self.var[plane..plane + tile];
            for ((a, &s), &v) in acc[..tile].iter_mut().zip(lv).zip(vr) {
                // levels are <= 3 (asserted at program time); the mask
                // only elides the 4-entry bounds check.
                *a += row[(s & 3) as usize] * v;
            }
        }
    }

    /// Sensed (noise-applied) currents of `tile` strings starting at
    /// `base`, via the tiled core — shared by [`Self::search_range`] and
    /// the noisy fused path, so the bit-identity contract (series order,
    /// division, in-order noise draws) lives in exactly one place.
    #[inline]
    fn tile_currents(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        base: usize,
        tile: usize,
        acc: &mut [f32; SENSE_TILE],
        currents: &mut [f64; SENSE_TILE],
    ) {
        self.tile_series(rows, base, tile, acc);
        for (current, &series) in currents[..tile].iter_mut().zip(acc[..tile].iter()) {
            *current = self.params.v_bl / series as f64;
        }
        if self.variation.read_sigma != 0.0 {
            self.variation.read_currents(&mut currents[..tile], &mut self.rng);
        }
    }

    /// Fused sense→vote→accumulate over the strings in
    /// `[first, first + count)`: drive `wordline`, sense every string,
    /// convert each sensed current into ladder votes, and add
    /// `weight * votes` into the matching `scores` slot — the L3 hot
    /// path, replacing the currents-`Vec` round-trip of the scalar
    /// reference ([`Self::sense_votes_range_naive`]).
    ///
    /// On the ideal path (no read noise) the ladder compare runs in the
    /// **series-resistance domain** ([`SeriesRungs`]): the per-string
    /// `v_bl / series` division disappears, and the exact-boundary rungs
    /// keep the votes bit-identical to the current-domain compare. The
    /// noisy path computes real currents (read noise consumes the block
    /// RNG in string order, exactly like the reference) and routes each
    /// tile through [`SenseLadder::votes_batch`].
    pub fn sense_votes_range(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        let rows = self.wordline_rows(wordline);
        let mut acc = [0f32; SENSE_TILE];
        if self.variation.read_sigma == 0.0 {
            if self.rung_thresholds.as_slice() != ladder.thresholds() {
                self.rung_thresholds.clear();
                self.rung_thresholds.extend_from_slice(ladder.thresholds());
                self.rungs = ladder.series_rungs(self.params.v_bl);
            }
            let mut done = 0;
            while done < count {
                let tile = (count - done).min(SENSE_TILE);
                self.tile_series(&rows, first + done, tile, &mut acc);
                for (score, &series) in scores[done..done + tile].iter_mut().zip(&acc) {
                    *score += weight * self.rungs.votes_for_series(series) as f64;
                }
                done += tile;
            }
        } else {
            let mut currents = [0f64; SENSE_TILE];
            let mut done = 0;
            while done < count {
                let tile = (count - done).min(SENSE_TILE);
                self.tile_currents(&rows, first + done, tile, &mut acc, &mut currents);
                self.votes_scratch.clear();
                ladder.votes_batch(&currents[..tile], &mut self.votes_scratch);
                let votes = &self.votes_scratch;
                for (score, &v) in scores[done..done + tile].iter_mut().zip(votes) {
                    *score += weight * v as f64;
                }
                done += tile;
            }
        }
    }

    /// The scalar reference sense path — the pre-tiling kernel retained
    /// verbatim as the correctness oracle for the kernel-equivalence
    /// property tests (`rust/tests/test_kernel_equivalence.rs`) and as
    /// the baseline of the `perf_kernel` microbench. Bit-identical to
    /// [`Self::sense_votes_range`] (same per-string cell-sum order, same
    /// RNG draw order); not on any hot path.
    pub fn sense_votes_range_naive(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        for (score, idx) in scores.iter_mut().zip(first..first + count) {
            let current = self.string_current_ideal(idx, wordline);
            let current = if self.variation.read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            *score += weight * ladder.votes(current) as f64;
        }
    }

    /// Series-resistance sums of the strings `offset + idx` for the tile
    /// of indices `idx` — the gather twin of [`Self::tile_series`]. The
    /// per-string accumulation order is l = 0..23, so a string's f32 sum
    /// is bit-identical whether it is sensed through a contiguous range
    /// or an index list (the cascade parity tests hinge on this).
    #[inline]
    fn tile_series_select(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        idx: &[usize],
        acc: &mut [f32; SENSE_TILE],
    ) {
        acc[..idx.len()].fill(0.0);
        for (l, row) in rows.iter().enumerate() {
            let plane = l * self.capacity + offset;
            for (a, &i) in acc[..idx.len()].iter_mut().zip(idx) {
                let cell = plane + i;
                // levels are <= 3 (asserted at program time); the mask
                // only elides the 4-entry bounds check.
                *a += row[(self.levels[cell] & 3) as usize] * self.var[cell];
            }
        }
    }

    /// Sensed (noise-applied) currents of the tile of selected strings —
    /// gather twin of [`Self::tile_currents`]. Read noise consumes one
    /// RNG draw per sensed string, in index order, so selective sensing
    /// replays deterministically under a fixed seed.
    #[inline]
    fn tile_currents_select(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        idx: &[usize],
        acc: &mut [f32; SENSE_TILE],
        currents: &mut [f64; SENSE_TILE],
    ) {
        self.tile_series_select(rows, offset, idx, acc);
        for (current, &series) in currents[..idx.len()].iter_mut().zip(acc[..idx.len()].iter()) {
            *current = self.params.v_bl / series as f64;
        }
        if self.variation.read_sigma != 0.0 {
            self.variation.read_currents(&mut currents[..idx.len()], &mut self.rng);
        }
    }

    /// Selective fused sense→vote→accumulate: drive `wordline` and sense
    /// only the strings `offset + indices[j]`, adding `weight * votes`
    /// into `scores[j]` — the cascade refine kernel (string-select on a
    /// real die: the word-line application is shared, only the selected
    /// bit lines are sensed). `indices` must ascend strictly; sensing in
    /// index order keeps the noisy path's RNG draw order deterministic,
    /// and sensing `offset + 0..count` is bit-identical to
    /// [`Self::sense_votes_range`] over the same range (ideal *and*
    /// noisy paths — same tile boundaries, same in-order draws).
    pub fn sense_votes_select(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        let Some(&last) = indices.last() else {
            return;
        };
        debug_assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "selected indices must ascend strictly"
        );
        assert!(offset + last < self.programmed, "search beyond programmed region");
        let rows = self.wordline_rows(wordline);
        let mut acc = [0f32; SENSE_TILE];
        if self.variation.read_sigma == 0.0 {
            if self.rung_thresholds.as_slice() != ladder.thresholds() {
                self.rung_thresholds.clear();
                self.rung_thresholds.extend_from_slice(ladder.thresholds());
                self.rungs = ladder.series_rungs(self.params.v_bl);
            }
            let mut done = 0;
            while done < indices.len() {
                let tile = (indices.len() - done).min(SENSE_TILE);
                self.tile_series_select(&rows, offset, &indices[done..done + tile], &mut acc);
                for (score, &series) in scores[done..done + tile].iter_mut().zip(&acc) {
                    *score += weight * self.rungs.votes_for_series(series) as f64;
                }
                done += tile;
            }
        } else {
            let mut currents = [0f64; SENSE_TILE];
            let mut done = 0;
            while done < indices.len() {
                let tile = (indices.len() - done).min(SENSE_TILE);
                self.tile_currents_select(
                    &rows,
                    offset,
                    &indices[done..done + tile],
                    &mut acc,
                    &mut currents,
                );
                self.votes_scratch.clear();
                ladder.votes_batch(&currents[..tile], &mut self.votes_scratch);
                let votes = &self.votes_scratch;
                for (score, &v) in scores[done..done + tile].iter_mut().zip(votes) {
                    *score += weight * v as f64;
                }
                done += tile;
            }
        }
    }

    /// Scalar reference for [`Self::sense_votes_select`] (per-string
    /// gather, in-order RNG draws) — the oracle for the selective-kernel
    /// equivalence tests; not on any hot path.
    pub fn sense_votes_select_naive(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        if let Some(&last) = indices.last() {
            assert!(offset + last < self.programmed, "search beyond programmed region");
        }
        for (score, &idx) in scores.iter_mut().zip(indices) {
            let current = self.string_current_ideal(offset + idx, wordline);
            let current = if self.variation.read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            *score += weight * ladder.votes(current) as f64;
        }
    }

    /// Search: drive `wordline` and sense the strings in
    /// `[first, first + count)`, appending currents (with read noise) to
    /// `out`. Runs on the tiled cell-major core, so the currents are
    /// bit-identical to per-string [`Self::string_current_ideal`] plus
    /// in-order read noise, at fused-kernel memory throughput.
    pub fn search_range(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        out: &mut Vec<f64>,
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        out.reserve(count);
        let rows = self.wordline_rows(wordline);
        let mut acc = [0f32; SENSE_TILE];
        let mut currents = [0f64; SENSE_TILE];
        let mut done = 0;
        while done < count {
            let tile = (count - done).min(SENSE_TILE);
            self.tile_currents(&rows, first + done, tile, &mut acc, &mut currents);
            out.extend_from_slice(&currents[..tile]);
            done += tile;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, Rng};

    fn ideal_block(capacity: usize) -> McamBlock {
        McamBlock::new(capacity, McamParams::default(), VariationModel::IDEAL, 7)
    }

    /// Program `n` pseudo-random strings; calling twice with the same
    /// arguments yields bit-identical twins (same block RNG stream).
    fn random_block(n: usize, variation: VariationModel, seed: u64) -> McamBlock {
        let mut block = McamBlock::new(n, McamParams::default(), variation, seed);
        let mut rng = Rng::new(seed ^ 0xA5A5);
        let mut cells = [0u8; CELLS_PER_STRING];
        for _ in 0..n {
            for c in cells.iter_mut() {
                *c = rng.below(4) as u8;
            }
            block.program_string(&cells);
        }
        block
    }

    fn random_wordline(rng: &mut Rng) -> [u8; CELLS_PER_STRING] {
        let mut wl = [0u8; CELLS_PER_STRING];
        for c in wl.iter_mut() {
            *c = rng.below(4) as u8;
        }
        wl
    }

    #[test]
    fn perfect_match_draws_i_max() {
        let mut block = ideal_block(4);
        let cells = [2u8; CELLS_PER_STRING];
        let idx = block.program_string(&cells);
        let i = block.string_current_ideal(idx, &cells);
        assert_close(i, block.params().i_max(), 1e-9);
    }

    #[test]
    fn current_matches_series_formula() {
        let mut block = ideal_block(4);
        let mut cells = [0u8; CELLS_PER_STRING];
        cells[0] = 3;
        cells[1] = 1;
        let idx = block.program_string(&cells);
        let wordline = [0u8; CELLS_PER_STRING];
        let p = McamParams::default();
        let series = 22.0 * p.resistance(0) + p.resistance(3) + p.resistance(1);
        assert_close(
            block.string_current_ideal(idx, &wordline),
            p.v_bl / series,
            1e-9,
        );
    }

    #[test]
    fn bottleneck_ordering() {
        // Same total mismatch (6): max-3 string draws less than max-1.
        let mut block = ideal_block(4);
        let mut worst = [0u8; CELLS_PER_STRING];
        worst[0] = 3;
        worst[1] = 3;
        let mut best = [0u8; CELLS_PER_STRING];
        for c in best.iter_mut().take(6) {
            *c = 1;
        }
        let a = block.program_string(&worst);
        let b = block.program_string(&best);
        let wl = [0u8; CELLS_PER_STRING];
        assert!(block.string_current_ideal(a, &wl) < block.string_current_ideal(b, &wl));
    }

    #[test]
    fn search_range_collects_all() {
        let mut block = ideal_block(8);
        for v in 0..8u8 {
            block.program_string(&[v % 4; CELLS_PER_STRING]);
        }
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 8, &mut out);
        assert_eq!(out.len(), 8);
        // levels 0 and 4%4=0 strings draw the max current
        assert_close(out[0], 1.0, 1e-9);
        assert!(out[3] < out[2] && out[2] < out[1] && out[1] < out[0]);
    }

    #[test]
    fn string_levels_roundtrip() {
        let mut block = ideal_block(4);
        let mut cells = [0u8; CELLS_PER_STRING];
        for (l, c) in cells.iter_mut().enumerate() {
            *c = (l % 4) as u8;
        }
        let idx = block.program_string(&cells);
        assert_eq!(block.string_levels(idx), cells);
    }

    #[test]
    #[should_panic(expected = "full")]
    fn program_beyond_capacity_panics() {
        let mut block = ideal_block(1);
        block.program_string(&[0; CELLS_PER_STRING]);
        block.program_string(&[0; CELLS_PER_STRING]);
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn search_unprogrammed_panics() {
        let mut block = ideal_block(4);
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 1, &mut out);
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn sense_votes_beyond_programmed_panics() {
        let mut block = ideal_block(4);
        block.program_string(&[0; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores = vec![0f64; 2];
        block.sense_votes_range(&[0; CELLS_PER_STRING], 0, 2, &ladder, 1.0, &mut scores);
    }

    #[test]
    fn erase_resets() {
        let mut block = ideal_block(2);
        block.program_string(&[1; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
        block.erase();
        assert_eq!(block.programmed(), 0);
        block.program_string(&[2; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
    }

    #[test]
    fn variation_perturbs_currents() {
        let mut block = McamBlock::new(
            16,
            McamParams::default(),
            VariationModel { program_sigma: 0.2, read_sigma: 0.0 },
            9,
        );
        let cells = [1u8; CELLS_PER_STRING];
        for _ in 0..16 {
            block.program_string(&cells);
        }
        let mut out = Vec::new();
        block.search_range(&[1; CELLS_PER_STRING], 0, 16, &mut out);
        let mean = out.iter().sum::<f64>() / out.len() as f64;
        assert!(out.iter().any(|&c| (c - mean).abs() > 1e-6), "no spread");
    }

    #[test]
    fn fused_matches_naive_ideal_bitwise() {
        // No read noise: neither path consumes RNG at sense time, so
        // both can run on the same block. Scores must agree to the last
        // bit, including across tile boundaries and odd offsets.
        let variation = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 21);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(77);
        for (first, count) in [(0, 150), (0, 1), (3, 64), (5, 129), (64, 64), (149, 1)] {
            let wl = random_wordline(&mut rng);
            let mut fused = vec![0.125f64; count];
            let mut naive = vec![0.125f64; count];
            block.sense_votes_range(&wl, first, count, &ladder, 0.375, &mut fused);
            block.sense_votes_range_naive(&wl, first, count, &ladder, 0.375, &mut naive);
            assert_eq!(fused, naive, "range ({first}, {count})");
        }
    }

    #[test]
    fn fused_matches_naive_noisy_bitwise() {
        // Read noise consumes the block RNG per sensed string, so the
        // oracle runs on an identically seeded twin; repeated calls keep
        // the two RNG streams aligned draw for draw.
        let variation = VariationModel { program_sigma: 0.15, read_sigma: 0.05 };
        let mut a = random_block(130, variation, 33);
        let mut b = random_block(130, variation, 33);
        let ladder = SenseLadder::new(&McamParams::default(), 12);
        let mut rng = Rng::new(99);
        for (first, count) in [(0, 130), (7, 65), (0, 64), (129, 1), (40, 13)] {
            let wl = random_wordline(&mut rng);
            let mut fused = vec![0f64; count];
            let mut naive = vec![0f64; count];
            a.sense_votes_range(&wl, first, count, &ladder, 1.5, &mut fused);
            b.sense_votes_range_naive(&wl, first, count, &ladder, 1.5, &mut naive);
            assert_eq!(fused, naive, "range ({first}, {count})");
        }
    }

    #[test]
    fn search_range_matches_scalar_reference_noisy() {
        // search_range runs on the tiled core; currents must stay
        // bit-identical to the per-string scalar walk with in-order
        // read-noise draws (a twin block supplies the aligned stream).
        let variation = VariationModel { program_sigma: 0.1, read_sigma: 0.08 };
        let mut a = random_block(100, variation, 5);
        let mut b = random_block(100, variation, 5);
        let mut rng = Rng::new(13);
        for (first, count) in [(0, 100), (3, 70), (99, 1)] {
            let wl = random_wordline(&mut rng);
            let mut tiled = Vec::new();
            a.search_range(&wl, first, count, &mut tiled);
            let variation = b.variation;
            let scalar: Vec<f64> = (first..first + count)
                .map(|idx| {
                    let current = b.string_current_ideal(idx, &wl);
                    variation.read_current(current, &mut b.rng)
                })
                .collect();
            assert_eq!(tiled, scalar, "range ({first}, {count})");
        }
    }

    #[test]
    fn fused_perfect_match_takes_full_ladder() {
        let mut block = ideal_block(4);
        let cells = [2u8; CELLS_PER_STRING];
        block.program_string(&cells);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut scores = vec![0f64; 1];
        block.sense_votes_range(&cells, 0, 1, &ladder, 1.0, &mut scores);
        // i_max clears every threshold (they sit strictly inside the range)
        assert_close(scores[0], 16.0, 1e-12);
    }

    #[test]
    fn select_full_range_matches_range_bitwise() {
        // Sensing the index list 0..count must be indistinguishable from
        // the contiguous range kernel — ideal AND noisy (same per-string
        // f32 sums, same tile boundaries, same in-order RNG draws). This
        // is the device-level hinge of the cascade parity tests.
        for variation in [
            VariationModel { program_sigma: 0.2, read_sigma: 0.0 },
            VariationModel { program_sigma: 0.15, read_sigma: 0.05 },
        ] {
            let mut a = random_block(130, variation, 57);
            let mut b = random_block(130, variation, 57);
            let ladder = SenseLadder::new(&McamParams::default(), 16);
            let mut rng = Rng::new(3);
            for (first, count) in [(0usize, 130usize), (5, 65), (64, 64), (129, 1)] {
                let wl = random_wordline(&mut rng);
                let indices: Vec<usize> = (0..count).collect();
                let mut selected = vec![0.25f64; count];
                let mut ranged = vec![0.25f64; count];
                a.sense_votes_select(&wl, first, &indices, &ladder, 1.5, &mut selected);
                b.sense_votes_range(&wl, first, count, &ladder, 1.5, &mut ranged);
                assert_eq!(
                    selected, ranged,
                    "sigma {:?}, range ({first}, {count})",
                    variation.read_sigma
                );
            }
        }
    }

    #[test]
    fn select_matches_naive_ideal_bitwise() {
        let variation = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 23);
        let ladder = SenseLadder::new(&McamParams::default(), 12);
        let mut rng = Rng::new(71);
        for trial in 0..6 {
            let wl = random_wordline(&mut rng);
            // random strictly ascending subset (≈ half the strings)
            let indices: Vec<usize> = (0..150).filter(|_| rng.below(2) == 0).collect();
            let mut fused = vec![0.5f64; indices.len()];
            let mut naive = vec![0.5f64; indices.len()];
            block.sense_votes_select(&wl, 0, &indices, &ladder, 0.75, &mut fused);
            block.sense_votes_select_naive(&wl, 0, &indices, &ladder, 0.75, &mut naive);
            assert_eq!(fused, naive, "trial {trial}, {} indices", indices.len());
        }
    }

    #[test]
    fn select_matches_naive_noisy_bitwise() {
        // Read noise consumes the block RNG per selected string, in index
        // order — an identically seeded twin supplies the aligned stream.
        let variation = VariationModel { program_sigma: 0.15, read_sigma: 0.05 };
        let mut a = random_block(120, variation, 91);
        let mut b = random_block(120, variation, 91);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(15);
        for trial in 0..5 {
            let wl = random_wordline(&mut rng);
            let indices: Vec<usize> = (0..120).filter(|_| rng.below(3) == 0).collect();
            let mut fused = vec![0f64; indices.len()];
            let mut naive = vec![0f64; indices.len()];
            a.sense_votes_select(&wl, 0, &indices, &ladder, 1.0, &mut fused);
            b.sense_votes_select_naive(&wl, 0, &indices, &ladder, 1.0, &mut naive);
            assert_eq!(fused, naive, "trial {trial}, {} indices", indices.len());
        }
    }

    #[test]
    fn select_respects_offset() {
        // offset + index addressing must hit exactly the same strings as
        // absolute indices.
        let mut block = random_block(80, VariationModel { program_sigma: 0.3, read_sigma: 0.0 }, 6);
        let ladder = SenseLadder::new(&McamParams::default(), 8);
        let mut rng = Rng::new(44);
        let wl = random_wordline(&mut rng);
        let offset = 40;
        let rel = [0usize, 3, 7, 39];
        let abs: Vec<usize> = rel.iter().map(|&i| offset + i).collect();
        let mut with_offset = vec![0f64; rel.len()];
        let mut absolute = vec![0f64; abs.len()];
        block.sense_votes_select(&wl, offset, &rel, &ladder, 1.0, &mut with_offset);
        block.sense_votes_select(&wl, 0, &abs, &ladder, 1.0, &mut absolute);
        assert_eq!(with_offset, absolute);
    }

    #[test]
    fn select_empty_is_noop() {
        let mut block = ideal_block(4);
        block.program_string(&[1; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores: Vec<f64> = Vec::new();
        block.sense_votes_select(&[0; CELLS_PER_STRING], 0, &[], &ladder, 1.0, &mut scores);
        assert!(scores.is_empty());
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn select_beyond_programmed_panics() {
        let mut block = ideal_block(4);
        block.program_string(&[0; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores = vec![0f64; 1];
        block.sense_votes_select(&[0; CELLS_PER_STRING], 0, &[1], &ladder, 1.0, &mut scores);
    }

    #[test]
    fn rung_cache_tracks_ladder_changes() {
        let mut block = random_block(40, VariationModel::IDEAL, 3);
        let mut rng = Rng::new(8);
        let wl = random_wordline(&mut rng);
        for len in [4usize, 16, 8] {
            let ladder = SenseLadder::new(&McamParams::default(), len);
            let mut fused = vec![0f64; 40];
            let mut naive = vec![0f64; 40];
            block.sense_votes_range(&wl, 0, 40, &ladder, 1.0, &mut fused);
            block.sense_votes_range_naive(&wl, 0, 40, &ladder, 1.0, &mut naive);
            assert_eq!(fused, naive, "ladder depth {len}");
        }
    }
}
