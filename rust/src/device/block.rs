//! A NAND-flash MCAM block: up to 128K strings × 24 MLC unit cells.
//!
//! Supports the two operations of the IMAS system [14]:
//!
//! * **program** — write 24 cell levels into a string (with program-time
//!   variation sampled per cell), and
//! * **search** — drive 24 word-line levels and read the resulting
//!   series-conductance current of selected strings.
//!
//! The sense path is the crate's performance-critical kernel (~3M cell
//! evaluations per engine iteration at full block occupancy). Cells are
//! stored **cell-major** (structure-of-arrays: one plane per word line,
//! strings contiguous within a plane) and sensed by the fused, tiled
//! sense→vote→accumulate kernel [`McamBlock::sense_votes_range`].
//!
//! The kernel comes in layered variants (see [`KernelVariant`] and
//! DESIGN.md §Perf), all bit-identical on every path:
//!
//! * [`McamBlock::sense_votes_range_naive`] — the pre-tiling per-string
//!   scalar walk, the reference oracle;
//! * [`McamBlock::sense_votes_range_scalar`] — the tiled scalar fused
//!   kernel (PR 2), retained verbatim as the second oracle and the
//!   `perf_kernel` baseline;
//! * [`McamBlock::sense_votes_range_int`] — the default hot path:
//!   same f32 series tiles, but ladder votes are counted branchlessly
//!   into an `i16`/`i32` tile accumulator (integer-vote accumulation);
//! * `sense_votes_range_simd` (`--features simd`, nightly) — the
//!   portable `std::simd` tile loop over the same plane-contiguous
//!   strides.
//!
//! [`McamBlock::sense_votes_range`] / [`McamBlock::sense_votes_select`]
//! dispatch to the build's active variant on the ideal path; the noisy
//! path is one shared body (in-order RNG draws), so every variant is
//! bit-identical there by construction. The differential harness in
//! `rust/tests/test_kernel_equivalence.rs` sweeps all of them.

use super::faults::FaultModel;
use super::sense::{SenseLadder, SeriesRungs};
use super::variation::VariationModel;
use super::McamParams;
use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// Strings per tile of the fused sense kernel: the f32 accumulator tile
/// (256 B) stays register/L1-resident while the 24 cell planes stream
/// through it, and 64 independent per-string sums give the pipeline
/// enough ILP to hide the dependent-add latency the scalar walk
/// serializes on.
const SENSE_TILE: usize = 64;

/// The fused-kernel implementation a build dispatches to on the ideal
/// (noise-free) path — decided at compile time by the `simd` cargo
/// feature (see [`McamBlock::active_kernel`]). Every variant is
/// bit-identical; the distinction is purely how the tile work is
/// scheduled, and benches/CI use the name to label perf records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelVariant {
    /// Tiled scalar fused kernel with break-loop series-domain voting —
    /// the retained PR-2 path, never dispatched to but kept callable as
    /// the correctness oracle and bench baseline.
    ScalarFused,
    /// Scalar fused kernel with branchless integer-vote tile
    /// accumulation (`i16`/`i32`) — the default-build hot path.
    IntegerAccum,
    /// Portable `std::simd` tile loop (`--features simd`, nightly).
    Simd,
}

impl KernelVariant {
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::ScalarFused => "scalar-fused",
            KernelVariant::IntegerAccum => "integer-accum",
            KernelVariant::Simd => "simd",
        }
    }
}

/// Ladder depths beyond `i16::MAX` widen the per-tile vote accumulator
/// from `i16` to `i32` lanes. A string earns at most one vote per rung
/// per kernel call, so while the ladder fits in `i16` the narrow
/// accumulator provably cannot overflow — and a `Vec`-backed ladder can
/// never outgrow `i32` (its length is far below `i32::MAX`). The exact
/// boundary is pinned by the `vote_saturating_*` unit tests below.
pub const fn vote_accumulator_widens(ladder_len: usize) -> bool {
    ladder_len > i16::MAX as usize
}

/// Branchless integer-vote tile count: `votes[i]` = number of rungs at
/// or above `series[i]`. The rungs descend, so the cleared set is a
/// prefix and counting **all** cleared rungs equals the oracle's
/// break-at-first-miss count for every input
/// ([`SeriesRungs::votes_for_series_dense`] pins the equivalence). The
/// rung-major loop has no data-dependent branch, so it autovectorizes;
/// the `i16` fast path halves the accumulator traffic and widens to
/// `i32` only for ladders deeper than `i16::MAX`
/// ([`vote_accumulator_widens`]).
#[inline]
fn tile_votes_int(rungs: &[f32], series: &[f32], votes: &mut [i32; SENSE_TILE]) {
    let tile = series.len();
    if vote_accumulator_widens(rungs.len()) {
        votes[..tile].fill(0);
        for &r in rungs {
            for (v, &s) in votes[..tile].iter_mut().zip(series) {
                *v += (s <= r) as i32;
            }
        }
    } else {
        let mut votes16 = [0i16; SENSE_TILE];
        for &r in rungs {
            for (v, &s) in votes16[..tile].iter_mut().zip(series) {
                *v += (s <= r) as i16;
            }
        }
        for (w, &v) in votes[..tile].iter_mut().zip(&votes16[..tile]) {
            *w = v as i32;
        }
    }
}

/// Convert a tile of integer vote counts to weighted f64 scores —
/// `score += weight * votes` exactly as the scalar oracle's per-string
/// update. A `u32`-range integer converts to f64 exactly, and this is
/// the **same single multiply-add per slot per call** the oracle
/// performs, so integer accumulation changes no representable result
/// (the bitwise-equivalence argument in DESIGN.md §Perf).
#[inline]
fn accumulate_votes(weight: f64, votes: &[i32], scores: &mut [f64]) {
    for (score, &v) in scores.iter_mut().zip(votes) {
        *score += weight * v as f64;
    }
}

/// One MCAM block.
pub struct McamBlock {
    params: McamParams,
    variation: VariationModel,
    faults: FaultModel,
    capacity: usize,
    /// Programmed cell levels, cell-major (structure-of-arrays): plane
    /// `l` stores cell `l` of every string contiguously, at
    /// `levels[l * capacity + idx]`, so the sense kernel's string loop
    /// streams sequential memory (see DESIGN.md §Perf).
    levels: Vec<u8>,
    /// Program-time per-cell resistance variation factor, same cell-major
    /// plane layout. (Kept separate from the levels instead of expanding
    /// per-drive resistances: 120 B/string of traffic instead of 384 B —
    /// see DESIGN.md §Perf.)
    var: Vec<f32>,
    /// 4x4 match-resistance lookup `lut[q][s]` (L1-resident).
    lut: [[f32; 4]; 4],
    /// Thresholds the cached series-domain `rungs` were computed for.
    /// The ideal fused path votes in the series-resistance domain;
    /// rebuilding the exact rungs costs ~31 f64 divisions per threshold,
    /// so they are cached across calls and invalidated by exact
    /// threshold comparison.
    rung_thresholds: Vec<f64>,
    rungs: SeriesRungs,
    /// Per-tile vote scratch for the noisy fused path (reused across
    /// calls so the hot path never allocates).
    votes_scratch: Vec<u32>,
    programmed: usize,
    rng: Rng,
}

impl McamBlock {
    pub fn new(
        capacity: usize,
        params: McamParams,
        variation: VariationModel,
        seed: u64,
    ) -> McamBlock {
        McamBlock {
            lut: params.resistance_lut(),
            params,
            variation,
            faults: FaultModel::NONE,
            capacity,
            levels: vec![0; capacity * CELLS_PER_STRING],
            var: vec![1.0; capacity * CELLS_PER_STRING],
            rung_thresholds: Vec::new(),
            rungs: SeriesRungs::default(),
            votes_scratch: Vec::new(),
            programmed: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn programmed(&self) -> usize {
        self.programmed
    }

    pub fn params(&self) -> &McamParams {
        &self.params
    }

    pub fn variation(&self) -> &VariationModel {
        &self.variation
    }

    /// Erase the block (programmed count returns to zero; variation is
    /// resampled on the next program, modeling a program/erase cycle).
    pub fn erase(&mut self) {
        self.programmed = 0;
    }

    /// Set the fault-injection model applied to subsequently programmed
    /// strings (reliability ablations).
    pub fn set_faults(&mut self, faults: FaultModel) {
        self.faults = faults;
    }

    /// Program the next free string with `cells` levels. Returns the
    /// string index.
    pub fn program_string(&mut self, cells: &[u8; CELLS_PER_STRING]) -> usize {
        assert!(
            self.programmed < self.capacity,
            "MCAM block full ({} strings)",
            self.capacity
        );
        let mut cells = *cells;
        if !self.faults.is_none() {
            self.faults.corrupt_string(&mut cells, &mut self.rng);
        }
        let idx = self.programmed;
        // Scatter across the cell planes; the per-cell RNG draw order
        // (l = 0..23) matches the string-major layout this replaced, so
        // seeded replays stay bit-identical.
        for (l, &s) in cells.iter().enumerate() {
            assert!(s <= 3, "cell level {s} out of range");
            let cell = l * self.capacity + idx;
            self.levels[cell] = s;
            self.var[cell] = self.variation.cell_factor(&mut self.rng);
        }
        self.programmed += 1;
        idx
    }

    /// Overwrite the cell *levels* of an already-programmed string in
    /// place, leaving its variation factors untouched and consuming **no**
    /// RNG draws. This is the fault-overlay / scrub-rewrite hook
    /// (DESIGN.md §Reliability): the engine computes the corrupted (or
    /// healed) levels through the pure-hash
    /// [`crate::device::faults::FaultState`] and materializes them here,
    /// so applying or clearing faults never perturbs the seeded
    /// program-variation or read-noise streams.
    pub fn rewrite_cells(&mut self, idx: usize, cells: &[u8; CELLS_PER_STRING]) {
        assert!(idx < self.programmed, "rewrite of unprogrammed string {idx}");
        for (l, &s) in cells.iter().enumerate() {
            assert!(s <= 3, "cell level {s} out of range");
            self.levels[l * self.capacity + idx] = s;
        }
    }

    /// Programmed levels of string `idx`, gathered across the cell
    /// planes (test/debug).
    pub fn string_levels(&self, idx: usize) -> [u8; CELLS_PER_STRING] {
        let mut cells = [0u8; CELLS_PER_STRING];
        for (l, cell) in cells.iter_mut().enumerate() {
            *cell = self.levels[l * self.capacity + idx];
        }
        cells
    }

    /// Ideal (noise-free) current of string `idx` under `wordline` — the
    /// scalar reference path (per-string plane gather, double-indexed
    /// LUT). The fused kernel reproduces its f32 cell-sum order
    /// (l = 0..23) bit-for-bit.
    #[inline]
    pub fn string_current_ideal(&self, idx: usize, wordline: &[u8; CELLS_PER_STRING]) -> f64 {
        let mut series = 0f32;
        for (l, &q) in wordline.iter().enumerate() {
            debug_assert!(q <= 3);
            let cell = l * self.capacity + idx;
            series += self.lut[q as usize][self.levels[cell] as usize] * self.var[cell];
        }
        self.params.v_bl / series as f64
    }

    /// Hoist the word-line gather: for a fixed drive, cell `l` always
    /// selects LUT row `lut[wordline[l]]`, so the 24×4 row table is
    /// built once per sense call instead of double-indexing the LUT per
    /// cell per string.
    #[inline]
    fn wordline_rows(&self, wordline: &[u8; CELLS_PER_STRING]) -> [[f32; 4]; CELLS_PER_STRING] {
        let mut rows = [[0f32; 4]; CELLS_PER_STRING];
        for (row, &q) in rows.iter_mut().zip(wordline) {
            debug_assert!(q <= 3);
            *row = self.lut[q as usize];
        }
        rows
    }

    /// Series-resistance sums of `tile` strings starting at `base`,
    /// streamed plane by plane with the hoisted word-line rows. The
    /// per-string accumulation order is l = 0..23 exactly as in
    /// [`Self::string_current_ideal`], so the f32 sums are bit-identical
    /// to the scalar reference.
    #[inline]
    fn tile_series(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        base: usize,
        tile: usize,
        acc: &mut [f32; SENSE_TILE],
    ) {
        acc[..tile].fill(0.0);
        for (l, row) in rows.iter().enumerate() {
            let plane = l * self.capacity + base;
            let lv = &self.levels[plane..plane + tile];
            let vr = &self.var[plane..plane + tile];
            for ((a, &s), &v) in acc[..tile].iter_mut().zip(lv).zip(vr) {
                // levels are <= 3 (asserted at program time); the mask
                // only elides the 4-entry bounds check.
                *a += row[(s & 3) as usize] * v;
            }
        }
    }

    /// Sensed (noise-applied) currents of `tile` strings starting at
    /// `base`, via the tiled core — shared by [`Self::search_range`] and
    /// the noisy fused path, so the bit-identity contract (series order,
    /// division, in-order noise draws) lives in exactly one place.
    #[inline]
    fn tile_currents(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        base: usize,
        tile: usize,
        acc: &mut [f32; SENSE_TILE],
        currents: &mut [f64; SENSE_TILE],
    ) {
        self.tile_series(rows, base, tile, acc);
        for (current, &series) in currents[..tile].iter_mut().zip(acc[..tile].iter()) {
            *current = self.params.v_bl / series as f64;
        }
        if self.variation.read_sigma != 0.0 {
            self.variation.read_currents(&mut currents[..tile], &mut self.rng);
        }
    }

    /// The fused-kernel variant this build dispatches to on the ideal
    /// path: [`KernelVariant::Simd`] under `--features simd`, otherwise
    /// [`KernelVariant::IntegerAccum`]. [`KernelVariant::ScalarFused`]
    /// is never the dispatch target — it is the retained oracle,
    /// callable explicitly via [`Self::sense_votes_range_scalar`].
    pub const fn active_kernel() -> KernelVariant {
        if cfg!(feature = "simd") {
            KernelVariant::Simd
        } else {
            KernelVariant::IntegerAccum
        }
    }

    /// Refresh the cached series-domain rungs if `ladder` changed since
    /// the last ideal-path sense (compared by exact threshold values).
    #[inline]
    fn ensure_rungs(&mut self, ladder: &SenseLadder) {
        if self.rung_thresholds.as_slice() != ladder.thresholds() {
            self.rung_thresholds.clear();
            self.rung_thresholds.extend_from_slice(ladder.thresholds());
            self.rungs = ladder.series_rungs(self.params.v_bl);
        }
    }

    /// Fused sense→vote→accumulate over the strings in
    /// `[first, first + count)`: drive `wordline`, sense every string,
    /// convert each sensed current into ladder votes, and add
    /// `weight * votes` into the matching `scores` slot — the L3 hot
    /// path behind the engine's shard scorer (`Shard::score_batch`),
    /// the cascade scans, and the routing tier.
    ///
    /// Dispatches to the build's [`Self::active_kernel`] on the ideal
    /// path (no read noise): integer-vote accumulation by default, the
    /// portable-SIMD tile loop under `--features simd`. Both run the
    /// ladder compare in the **series-resistance domain**
    /// ([`SeriesRungs`]): the per-string `v_bl / series` division
    /// disappears, and the exact-boundary rungs keep the votes
    /// bit-identical to the current-domain compare. The noisy path is
    /// the single shared body every variant uses (real currents, read
    /// noise consuming the block RNG in string order exactly like the
    /// reference, tiles routed through [`SenseLadder::votes_batch`]).
    pub fn sense_votes_range(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            #[cfg(feature = "simd")]
            self.range_ideal_simd(&rows, first, count, weight, scores);
            #[cfg(not(feature = "simd"))]
            self.range_ideal_int(&rows, first, count, weight, scores);
        } else {
            self.range_noisy(&rows, first, count, ladder, weight, scores);
        }
    }

    /// The tiled **scalar fused** kernel (PR 2), retained verbatim as
    /// the second correctness oracle (after the per-string naive walk)
    /// and the `perf_kernel` baseline the SIMD speedup is measured
    /// against. Bit-identical to [`Self::sense_votes_range`] on every
    /// path — the differential harness asserts it.
    pub fn sense_votes_range_scalar(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            let mut acc = [0f32; SENSE_TILE];
            let mut done = 0;
            while done < count {
                let tile = (count - done).min(SENSE_TILE);
                self.tile_series(&rows, first + done, tile, &mut acc);
                for (score, &series) in scores[done..done + tile].iter_mut().zip(&acc) {
                    *score += weight * self.rungs.votes_for_series(series) as f64;
                }
                done += tile;
            }
        } else {
            self.range_noisy(&rows, first, count, ladder, weight, scores);
        }
    }

    /// The **integer-vote accumulation** kernel — the default-build
    /// dispatch target of [`Self::sense_votes_range`], callable
    /// explicitly so the differential harness and `perf_kernel` can
    /// exercise it regardless of the active feature set. Ladder votes
    /// are counted branchlessly into an `i16`/`i32` tile accumulator
    /// (`tile_votes_int`) and converted to weighted f64 scores once per
    /// slot per call — bitwise identical to the scalar fused oracle
    /// (argument on `accumulate_votes` and in DESIGN.md §Perf).
    pub fn sense_votes_range_int(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            self.range_ideal_int(&rows, first, count, weight, scores);
        } else {
            self.range_noisy(&rows, first, count, ladder, weight, scores);
        }
    }

    /// The portable **`std::simd`** kernel (`--features simd`, nightly)
    /// — the dispatch target of [`Self::sense_votes_range`] when the
    /// feature is on. Same plane-contiguous strides and per-string
    /// l = 0..23 sum order as the scalar tile (SIMD runs *across*
    /// strings, never across a string's cells), so the f32 series sums
    /// — and therefore the votes — are bit-identical.
    #[cfg(feature = "simd")]
    pub fn sense_votes_range_simd(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            self.range_ideal_simd(&rows, first, count, weight, scores);
        } else {
            self.range_noisy(&rows, first, count, ladder, weight, scores);
        }
    }

    /// Ideal-path integer-accumulation tile loop shared by the
    /// dispatcher and [`Self::sense_votes_range_int`]. Caller must have
    /// run [`Self::ensure_rungs`].
    fn range_ideal_int(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        first: usize,
        count: usize,
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut votes = [0i32; SENSE_TILE];
        let mut done = 0;
        while done < count {
            let tile = (count - done).min(SENSE_TILE);
            self.tile_series(rows, first + done, tile, &mut acc);
            tile_votes_int(self.rungs.rungs(), &acc[..tile], &mut votes);
            accumulate_votes(weight, &votes[..tile], &mut scores[done..done + tile]);
            done += tile;
        }
    }

    /// Ideal-path portable-SIMD tile loop. Caller must have run
    /// [`Self::ensure_rungs`].
    #[cfg(feature = "simd")]
    fn range_ideal_simd(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        first: usize,
        count: usize,
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut votes = [0i32; SENSE_TILE];
        let mut done = 0;
        while done < count {
            let tile = (count - done).min(SENSE_TILE);
            simd_core::tile_series(
                rows,
                &self.levels,
                &self.var,
                self.capacity,
                first + done,
                tile,
                &mut acc,
            );
            simd_core::tile_votes(self.rungs.rungs(), &acc[..tile], &mut votes);
            accumulate_votes(weight, &votes[..tile], &mut scores[done..done + tile]);
            done += tile;
        }
    }

    /// Noisy-path range core shared by **every** kernel variant: tile
    /// currents (read noise consumes the block RNG in string order) →
    /// [`SenseLadder::votes_batch`] → weighted f64 accumulate. One body
    /// means the variants are bit-identical under noise — and draw the
    /// RNG identically — by construction, which is why the differential
    /// harness pins the noisy-path tolerance at exactly zero.
    fn range_noisy(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut currents = [0f64; SENSE_TILE];
        let mut done = 0;
        while done < count {
            let tile = (count - done).min(SENSE_TILE);
            self.tile_currents(rows, first + done, tile, &mut acc, &mut currents);
            self.votes_scratch.clear();
            ladder.votes_batch(&currents[..tile], &mut self.votes_scratch);
            let votes = &self.votes_scratch;
            for (score, &v) in scores[done..done + tile].iter_mut().zip(votes) {
                *score += weight * v as f64;
            }
            done += tile;
        }
    }

    /// The scalar reference sense path — the pre-tiling kernel retained
    /// verbatim as the correctness oracle for the kernel-equivalence
    /// property tests (`rust/tests/test_kernel_equivalence.rs`) and as
    /// the baseline of the `perf_kernel` microbench. Bit-identical to
    /// [`Self::sense_votes_range`] (same per-string cell-sum order, same
    /// RNG draw order); not on any hot path.
    pub fn sense_votes_range_naive(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        assert_eq!(scores.len(), count, "one score slot per sensed string");
        for (score, idx) in scores.iter_mut().zip(first..first + count) {
            let current = self.string_current_ideal(idx, wordline);
            let current = if self.variation.read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            *score += weight * ladder.votes(current) as f64;
        }
    }

    /// Series-resistance sums of the strings `offset + idx` for the tile
    /// of indices `idx` — the gather twin of [`Self::tile_series`]. The
    /// per-string accumulation order is l = 0..23, so a string's f32 sum
    /// is bit-identical whether it is sensed through a contiguous range
    /// or an index list (the cascade parity tests hinge on this).
    #[inline]
    fn tile_series_select(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        idx: &[usize],
        acc: &mut [f32; SENSE_TILE],
    ) {
        acc[..idx.len()].fill(0.0);
        for (l, row) in rows.iter().enumerate() {
            let plane = l * self.capacity + offset;
            for (a, &i) in acc[..idx.len()].iter_mut().zip(idx) {
                let cell = plane + i;
                // levels are <= 3 (asserted at program time); the mask
                // only elides the 4-entry bounds check.
                *a += row[(self.levels[cell] & 3) as usize] * self.var[cell];
            }
        }
    }

    /// Sensed (noise-applied) currents of the tile of selected strings —
    /// gather twin of [`Self::tile_currents`]. Read noise consumes one
    /// RNG draw per sensed string, in index order, so selective sensing
    /// replays deterministically under a fixed seed.
    #[inline]
    fn tile_currents_select(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        idx: &[usize],
        acc: &mut [f32; SENSE_TILE],
        currents: &mut [f64; SENSE_TILE],
    ) {
        self.tile_series_select(rows, offset, idx, acc);
        for (current, &series) in currents[..idx.len()].iter_mut().zip(acc[..idx.len()].iter()) {
            *current = self.params.v_bl / series as f64;
        }
        if self.variation.read_sigma != 0.0 {
            self.variation.read_currents(&mut currents[..idx.len()], &mut self.rng);
        }
    }

    /// Selective fused sense→vote→accumulate: drive `wordline` and sense
    /// only the strings `offset + indices[j]`, adding `weight * votes`
    /// into `scores[j]` — the cascade refine kernel (string-select on a
    /// real die: the word-line application is shared, only the selected
    /// bit lines are sensed). `indices` must ascend strictly; sensing in
    /// index order keeps the noisy path's RNG draw order deterministic,
    /// and sensing `offset + 0..count` is bit-identical to
    /// [`Self::sense_votes_range`] over the same range (ideal *and*
    /// noisy paths — same tile boundaries, same in-order draws).
    ///
    /// Dispatches exactly like [`Self::sense_votes_range`]: the ideal
    /// path runs the build's [`Self::active_kernel`] vote stage over
    /// gathered series sums, the noisy path is the shared body. The
    /// SIMD variant keeps the **gather** scalar (index lists defeat
    /// contiguous loads) and vectorizes only the vote count.
    pub fn sense_votes_select(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        let Some(&last) = indices.last() else {
            return;
        };
        debug_assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "selected indices must ascend strictly"
        );
        assert!(offset + last < self.programmed, "search beyond programmed region");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            #[cfg(feature = "simd")]
            self.select_ideal_simd(&rows, offset, indices, weight, scores);
            #[cfg(not(feature = "simd"))]
            self.select_ideal_int(&rows, offset, indices, weight, scores);
        } else {
            self.select_noisy(&rows, offset, indices, ladder, weight, scores);
        }
    }

    /// The tiled scalar fused selective kernel — oracle twin of
    /// [`Self::sense_votes_range_scalar`] for the select path.
    pub fn sense_votes_select_scalar(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        let Some(&last) = indices.last() else {
            return;
        };
        debug_assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "selected indices must ascend strictly"
        );
        assert!(offset + last < self.programmed, "search beyond programmed region");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            let mut acc = [0f32; SENSE_TILE];
            let mut done = 0;
            while done < indices.len() {
                let tile = (indices.len() - done).min(SENSE_TILE);
                self.tile_series_select(&rows, offset, &indices[done..done + tile], &mut acc);
                for (score, &series) in scores[done..done + tile].iter_mut().zip(&acc) {
                    *score += weight * self.rungs.votes_for_series(series) as f64;
                }
                done += tile;
            }
        } else {
            self.select_noisy(&rows, offset, indices, ladder, weight, scores);
        }
    }

    /// Integer-vote-accumulation selective kernel — explicit twin of
    /// [`Self::sense_votes_range_int`], the default-build dispatch
    /// target of [`Self::sense_votes_select`].
    pub fn sense_votes_select_int(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        let Some(&last) = indices.last() else {
            return;
        };
        debug_assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "selected indices must ascend strictly"
        );
        assert!(offset + last < self.programmed, "search beyond programmed region");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            self.select_ideal_int(&rows, offset, indices, weight, scores);
        } else {
            self.select_noisy(&rows, offset, indices, ladder, weight, scores);
        }
    }

    /// Portable-SIMD selective kernel (`--features simd`) — explicit
    /// twin of `sense_votes_range_simd`: scalar gather, SIMD vote count.
    #[cfg(feature = "simd")]
    pub fn sense_votes_select_simd(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        let Some(&last) = indices.last() else {
            return;
        };
        debug_assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "selected indices must ascend strictly"
        );
        assert!(offset + last < self.programmed, "search beyond programmed region");
        let rows = self.wordline_rows(wordline);
        if self.variation.read_sigma == 0.0 {
            self.ensure_rungs(ladder);
            self.select_ideal_simd(&rows, offset, indices, weight, scores);
        } else {
            self.select_noisy(&rows, offset, indices, ladder, weight, scores);
        }
    }

    /// Ideal-path integer-accumulation loop over gathered tiles. Caller
    /// must have run [`Self::ensure_rungs`].
    fn select_ideal_int(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut votes = [0i32; SENSE_TILE];
        let mut done = 0;
        while done < indices.len() {
            let tile = (indices.len() - done).min(SENSE_TILE);
            self.tile_series_select(rows, offset, &indices[done..done + tile], &mut acc);
            tile_votes_int(self.rungs.rungs(), &acc[..tile], &mut votes);
            accumulate_votes(weight, &votes[..tile], &mut scores[done..done + tile]);
            done += tile;
        }
    }

    /// Ideal-path SIMD-vote loop over gathered tiles (scalar gather —
    /// the index list defeats contiguous loads; the vote count is where
    /// the ladder-length work is). Caller must have run
    /// [`Self::ensure_rungs`].
    #[cfg(feature = "simd")]
    fn select_ideal_simd(
        &self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut votes = [0i32; SENSE_TILE];
        let mut done = 0;
        while done < indices.len() {
            let tile = (indices.len() - done).min(SENSE_TILE);
            self.tile_series_select(rows, offset, &indices[done..done + tile], &mut acc);
            simd_core::tile_votes(self.rungs.rungs(), &acc[..tile], &mut votes);
            accumulate_votes(weight, &votes[..tile], &mut scores[done..done + tile]);
            done += tile;
        }
    }

    /// Noisy-path select core shared by every kernel variant — gather
    /// twin of [`Self::range_noisy`], same one-body bit-identity
    /// guarantee.
    fn select_noisy(
        &mut self,
        rows: &[[f32; 4]; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        let mut acc = [0f32; SENSE_TILE];
        let mut currents = [0f64; SENSE_TILE];
        let mut done = 0;
        while done < indices.len() {
            let tile = (indices.len() - done).min(SENSE_TILE);
            self.tile_currents_select(
                rows,
                offset,
                &indices[done..done + tile],
                &mut acc,
                &mut currents,
            );
            self.votes_scratch.clear();
            ladder.votes_batch(&currents[..tile], &mut self.votes_scratch);
            let votes = &self.votes_scratch;
            for (score, &v) in scores[done..done + tile].iter_mut().zip(votes) {
                *score += weight * v as f64;
            }
            done += tile;
        }
    }

    /// Scalar reference for [`Self::sense_votes_select`] (per-string
    /// gather, in-order RNG draws) — the oracle for the selective-kernel
    /// equivalence tests; not on any hot path.
    pub fn sense_votes_select_naive(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        offset: usize,
        indices: &[usize],
        ladder: &SenseLadder,
        weight: f64,
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), indices.len(), "one score slot per sensed string");
        if let Some(&last) = indices.last() {
            assert!(offset + last < self.programmed, "search beyond programmed region");
        }
        for (score, &idx) in scores.iter_mut().zip(indices) {
            let current = self.string_current_ideal(offset + idx, wordline);
            let current = if self.variation.read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            *score += weight * ladder.votes(current) as f64;
        }
    }

    /// Search: drive `wordline` and sense the strings in
    /// `[first, first + count)`, appending currents (with read noise) to
    /// `out`. Runs on the tiled cell-major core, so the currents are
    /// bit-identical to per-string [`Self::string_current_ideal`] plus
    /// in-order read noise, at fused-kernel memory throughput.
    pub fn search_range(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        out: &mut Vec<f64>,
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        out.reserve(count);
        let rows = self.wordline_rows(wordline);
        let mut acc = [0f32; SENSE_TILE];
        let mut currents = [0f64; SENSE_TILE];
        let mut done = 0;
        while done < count {
            let tile = (count - done).min(SENSE_TILE);
            self.tile_currents(&rows, first + done, tile, &mut acc, &mut currents);
            out.extend_from_slice(&currents[..tile]);
            done += tile;
        }
    }
}

/// Portable `std::simd` tile cores (`--features simd`, nightly).
///
/// Layout notes (DESIGN.md §Perf): the cell planes are already
/// SoA-contiguous, so an 8-lane f32 vector covers 8 *strings* of one
/// word line — each lane's 24-term sum runs in the same l = 0..23 order
/// as the scalar kernel, which is what keeps the f32 series sums
/// bit-identical (f32 addition is commutative-unsafe across *terms*,
/// but lanes never mix terms between strings). The 4-entry LUT row is
/// applied by two mask selects on the level bits instead of a gather:
/// `row[s] = s&1 ? (s&2 ? row3 : row1) : (s&2 ? row2 : row0)`.
#[cfg(feature = "simd")]
mod simd_core {
    use super::{CELLS_PER_STRING, SENSE_TILE};
    use std::simd::prelude::*;

    const LANES: usize = 8;
    type F32s = Simd<f32, LANES>;
    type I32s = Simd<i32, LANES>;
    type U8s = Simd<u8, LANES>;
    type MaskI32 = Mask<i32, LANES>;

    /// SIMD twin of `McamBlock::tile_series`: series-resistance sums of
    /// `tile` strings starting at `base`, 8 strings per vector, scalar
    /// remainder for `tile % 8`.
    pub(super) fn tile_series(
        rows: &[[f32; 4]; CELLS_PER_STRING],
        levels: &[u8],
        var: &[f32],
        capacity: usize,
        base: usize,
        tile: usize,
        acc: &mut [f32; SENSE_TILE],
    ) {
        acc[..tile].fill(0.0);
        let vec_tile = tile - tile % LANES;
        for (l, row) in rows.iter().enumerate() {
            let plane = l * capacity + base;
            let lv = &levels[plane..plane + tile];
            let vr = &var[plane..plane + tile];
            let row0 = F32s::splat(row[0]);
            let row1 = F32s::splat(row[1]);
            let row2 = F32s::splat(row[2]);
            let row3 = F32s::splat(row[3]);
            let mut s = 0;
            while s < vec_tile {
                let lvls = U8s::from_slice(&lv[s..s + LANES]);
                let bit0: MaskI32 = (lvls & U8s::splat(1)).simd_ne(U8s::splat(0)).cast();
                let bit1: MaskI32 = (lvls & U8s::splat(2)).simd_ne(U8s::splat(0)).cast();
                let even = bit1.select(row2, row0);
                let odd = bit1.select(row3, row1);
                let conductance = bit0.select(odd, even);
                let v = F32s::from_slice(&vr[s..s + LANES]);
                let mut a = F32s::from_slice(&acc[s..s + LANES]);
                a += conductance * v;
                a.copy_to_slice(&mut acc[s..s + LANES]);
                s += LANES;
            }
            for ((a, &lvl), &v) in
                acc[vec_tile..tile].iter_mut().zip(&lv[vec_tile..]).zip(&vr[vec_tile..])
            {
                *a += row[(lvl & 3) as usize] * v;
            }
        }
    }

    /// SIMD twin of `tile_votes_int`: branchless cleared-rung count, 8
    /// strings per vector (`votes -= (series <= rung) mask`, a mask
    /// lane being -1), scalar remainder. Same full-ladder counting
    /// scheme, so the counts equal the break-loop oracle's (descending
    /// rungs ⇒ the cleared set is a prefix).
    pub(super) fn tile_votes(rungs: &[f32], series: &[f32], votes: &mut [i32; SENSE_TILE]) {
        let tile = series.len();
        votes[..tile].fill(0);
        let vec_tile = tile - tile % LANES;
        let mut s = 0;
        while s < vec_tile {
            let sv = F32s::from_slice(&series[s..s + LANES]);
            let mut v = I32s::splat(0);
            for &r in rungs {
                v -= sv.simd_le(F32s::splat(r)).to_int();
            }
            v.copy_to_slice(&mut votes[s..s + LANES]);
            s += LANES;
        }
        for (v, &x) in votes[vec_tile..tile].iter_mut().zip(&series[vec_tile..]) {
            let mut n = 0i32;
            for &r in rungs {
                n += (x <= r) as i32;
            }
            *v = n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, Rng};

    fn ideal_block(capacity: usize) -> McamBlock {
        McamBlock::new(capacity, McamParams::default(), VariationModel::IDEAL, 7)
    }

    /// Program `n` pseudo-random strings; calling twice with the same
    /// arguments yields bit-identical twins (same block RNG stream).
    fn random_block(n: usize, variation: VariationModel, seed: u64) -> McamBlock {
        let mut block = McamBlock::new(n, McamParams::default(), variation, seed);
        let mut rng = Rng::new(seed ^ 0xA5A5);
        let mut cells = [0u8; CELLS_PER_STRING];
        for _ in 0..n {
            for c in cells.iter_mut() {
                *c = rng.below(4) as u8;
            }
            block.program_string(&cells);
        }
        block
    }

    fn random_wordline(rng: &mut Rng) -> [u8; CELLS_PER_STRING] {
        let mut wl = [0u8; CELLS_PER_STRING];
        for c in wl.iter_mut() {
            *c = rng.below(4) as u8;
        }
        wl
    }

    #[test]
    fn perfect_match_draws_i_max() {
        let mut block = ideal_block(4);
        let cells = [2u8; CELLS_PER_STRING];
        let idx = block.program_string(&cells);
        let i = block.string_current_ideal(idx, &cells);
        assert_close(i, block.params().i_max(), 1e-9);
    }

    #[test]
    fn current_matches_series_formula() {
        let mut block = ideal_block(4);
        let mut cells = [0u8; CELLS_PER_STRING];
        cells[0] = 3;
        cells[1] = 1;
        let idx = block.program_string(&cells);
        let wordline = [0u8; CELLS_PER_STRING];
        let p = McamParams::default();
        let series = 22.0 * p.resistance(0) + p.resistance(3) + p.resistance(1);
        assert_close(
            block.string_current_ideal(idx, &wordline),
            p.v_bl / series,
            1e-9,
        );
    }

    #[test]
    fn bottleneck_ordering() {
        // Same total mismatch (6): max-3 string draws less than max-1.
        let mut block = ideal_block(4);
        let mut worst = [0u8; CELLS_PER_STRING];
        worst[0] = 3;
        worst[1] = 3;
        let mut best = [0u8; CELLS_PER_STRING];
        for c in best.iter_mut().take(6) {
            *c = 1;
        }
        let a = block.program_string(&worst);
        let b = block.program_string(&best);
        let wl = [0u8; CELLS_PER_STRING];
        assert!(block.string_current_ideal(a, &wl) < block.string_current_ideal(b, &wl));
    }

    #[test]
    fn search_range_collects_all() {
        let mut block = ideal_block(8);
        for v in 0..8u8 {
            block.program_string(&[v % 4; CELLS_PER_STRING]);
        }
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 8, &mut out);
        assert_eq!(out.len(), 8);
        // levels 0 and 4%4=0 strings draw the max current
        assert_close(out[0], 1.0, 1e-9);
        assert!(out[3] < out[2] && out[2] < out[1] && out[1] < out[0]);
    }

    #[test]
    fn string_levels_roundtrip() {
        let mut block = ideal_block(4);
        let mut cells = [0u8; CELLS_PER_STRING];
        for (l, c) in cells.iter_mut().enumerate() {
            *c = (l % 4) as u8;
        }
        let idx = block.program_string(&cells);
        assert_eq!(block.string_levels(idx), cells);
    }

    #[test]
    #[should_panic(expected = "full")]
    fn program_beyond_capacity_panics() {
        let mut block = ideal_block(1);
        block.program_string(&[0; CELLS_PER_STRING]);
        block.program_string(&[0; CELLS_PER_STRING]);
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn search_unprogrammed_panics() {
        let mut block = ideal_block(4);
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 1, &mut out);
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn sense_votes_beyond_programmed_panics() {
        let mut block = ideal_block(4);
        block.program_string(&[0; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores = vec![0f64; 2];
        block.sense_votes_range(&[0; CELLS_PER_STRING], 0, 2, &ladder, 1.0, &mut scores);
    }

    #[test]
    fn erase_resets() {
        let mut block = ideal_block(2);
        block.program_string(&[1; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
        block.erase();
        assert_eq!(block.programmed(), 0);
        block.program_string(&[2; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
    }

    #[test]
    fn variation_perturbs_currents() {
        let mut block = McamBlock::new(
            16,
            McamParams::default(),
            VariationModel { program_sigma: 0.2, read_sigma: 0.0 },
            9,
        );
        let cells = [1u8; CELLS_PER_STRING];
        for _ in 0..16 {
            block.program_string(&cells);
        }
        let mut out = Vec::new();
        block.search_range(&[1; CELLS_PER_STRING], 0, 16, &mut out);
        let mean = out.iter().sum::<f64>() / out.len() as f64;
        assert!(out.iter().any(|&c| (c - mean).abs() > 1e-6), "no spread");
    }

    #[test]
    fn fused_matches_naive_ideal_bitwise() {
        // No read noise: neither path consumes RNG at sense time, so
        // both can run on the same block. Scores must agree to the last
        // bit, including across tile boundaries and odd offsets.
        let variation = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 21);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(77);
        for (first, count) in [(0, 150), (0, 1), (3, 64), (5, 129), (64, 64), (149, 1)] {
            let wl = random_wordline(&mut rng);
            let mut fused = vec![0.125f64; count];
            let mut naive = vec![0.125f64; count];
            block.sense_votes_range(&wl, first, count, &ladder, 0.375, &mut fused);
            block.sense_votes_range_naive(&wl, first, count, &ladder, 0.375, &mut naive);
            assert_eq!(fused, naive, "range ({first}, {count})");
        }
    }

    #[test]
    fn fused_matches_naive_noisy_bitwise() {
        // Read noise consumes the block RNG per sensed string, so the
        // oracle runs on an identically seeded twin; repeated calls keep
        // the two RNG streams aligned draw for draw.
        let variation = VariationModel { program_sigma: 0.15, read_sigma: 0.05 };
        let mut a = random_block(130, variation, 33);
        let mut b = random_block(130, variation, 33);
        let ladder = SenseLadder::new(&McamParams::default(), 12);
        let mut rng = Rng::new(99);
        for (first, count) in [(0, 130), (7, 65), (0, 64), (129, 1), (40, 13)] {
            let wl = random_wordline(&mut rng);
            let mut fused = vec![0f64; count];
            let mut naive = vec![0f64; count];
            a.sense_votes_range(&wl, first, count, &ladder, 1.5, &mut fused);
            b.sense_votes_range_naive(&wl, first, count, &ladder, 1.5, &mut naive);
            assert_eq!(fused, naive, "range ({first}, {count})");
        }
    }

    #[test]
    fn search_range_matches_scalar_reference_noisy() {
        // search_range runs on the tiled core; currents must stay
        // bit-identical to the per-string scalar walk with in-order
        // read-noise draws (a twin block supplies the aligned stream).
        let variation = VariationModel { program_sigma: 0.1, read_sigma: 0.08 };
        let mut a = random_block(100, variation, 5);
        let mut b = random_block(100, variation, 5);
        let mut rng = Rng::new(13);
        for (first, count) in [(0, 100), (3, 70), (99, 1)] {
            let wl = random_wordline(&mut rng);
            let mut tiled = Vec::new();
            a.search_range(&wl, first, count, &mut tiled);
            let variation = b.variation;
            let scalar: Vec<f64> = (first..first + count)
                .map(|idx| {
                    let current = b.string_current_ideal(idx, &wl);
                    variation.read_current(current, &mut b.rng)
                })
                .collect();
            assert_eq!(tiled, scalar, "range ({first}, {count})");
        }
    }

    #[test]
    fn fused_perfect_match_takes_full_ladder() {
        let mut block = ideal_block(4);
        let cells = [2u8; CELLS_PER_STRING];
        block.program_string(&cells);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut scores = vec![0f64; 1];
        block.sense_votes_range(&cells, 0, 1, &ladder, 1.0, &mut scores);
        // i_max clears every threshold (they sit strictly inside the range)
        assert_close(scores[0], 16.0, 1e-12);
    }

    #[test]
    fn select_full_range_matches_range_bitwise() {
        // Sensing the index list 0..count must be indistinguishable from
        // the contiguous range kernel — ideal AND noisy (same per-string
        // f32 sums, same tile boundaries, same in-order RNG draws). This
        // is the device-level hinge of the cascade parity tests.
        for variation in [
            VariationModel { program_sigma: 0.2, read_sigma: 0.0 },
            VariationModel { program_sigma: 0.15, read_sigma: 0.05 },
        ] {
            let mut a = random_block(130, variation, 57);
            let mut b = random_block(130, variation, 57);
            let ladder = SenseLadder::new(&McamParams::default(), 16);
            let mut rng = Rng::new(3);
            for (first, count) in [(0usize, 130usize), (5, 65), (64, 64), (129, 1)] {
                let wl = random_wordline(&mut rng);
                let indices: Vec<usize> = (0..count).collect();
                let mut selected = vec![0.25f64; count];
                let mut ranged = vec![0.25f64; count];
                a.sense_votes_select(&wl, first, &indices, &ladder, 1.5, &mut selected);
                b.sense_votes_range(&wl, first, count, &ladder, 1.5, &mut ranged);
                assert_eq!(
                    selected, ranged,
                    "sigma {:?}, range ({first}, {count})",
                    variation.read_sigma
                );
            }
        }
    }

    #[test]
    fn select_matches_naive_ideal_bitwise() {
        let variation = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 23);
        let ladder = SenseLadder::new(&McamParams::default(), 12);
        let mut rng = Rng::new(71);
        for trial in 0..6 {
            let wl = random_wordline(&mut rng);
            // random strictly ascending subset (≈ half the strings)
            let indices: Vec<usize> = (0..150).filter(|_| rng.below(2) == 0).collect();
            let mut fused = vec![0.5f64; indices.len()];
            let mut naive = vec![0.5f64; indices.len()];
            block.sense_votes_select(&wl, 0, &indices, &ladder, 0.75, &mut fused);
            block.sense_votes_select_naive(&wl, 0, &indices, &ladder, 0.75, &mut naive);
            assert_eq!(fused, naive, "trial {trial}, {} indices", indices.len());
        }
    }

    #[test]
    fn select_matches_naive_noisy_bitwise() {
        // Read noise consumes the block RNG per selected string, in index
        // order — an identically seeded twin supplies the aligned stream.
        let variation = VariationModel { program_sigma: 0.15, read_sigma: 0.05 };
        let mut a = random_block(120, variation, 91);
        let mut b = random_block(120, variation, 91);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(15);
        for trial in 0..5 {
            let wl = random_wordline(&mut rng);
            let indices: Vec<usize> = (0..120).filter(|_| rng.below(3) == 0).collect();
            let mut fused = vec![0f64; indices.len()];
            let mut naive = vec![0f64; indices.len()];
            a.sense_votes_select(&wl, 0, &indices, &ladder, 1.0, &mut fused);
            b.sense_votes_select_naive(&wl, 0, &indices, &ladder, 1.0, &mut naive);
            assert_eq!(fused, naive, "trial {trial}, {} indices", indices.len());
        }
    }

    #[test]
    fn select_respects_offset() {
        // offset + index addressing must hit exactly the same strings as
        // absolute indices.
        let mut block = random_block(80, VariationModel { program_sigma: 0.3, read_sigma: 0.0 }, 6);
        let ladder = SenseLadder::new(&McamParams::default(), 8);
        let mut rng = Rng::new(44);
        let wl = random_wordline(&mut rng);
        let offset = 40;
        let rel = [0usize, 3, 7, 39];
        let abs: Vec<usize> = rel.iter().map(|&i| offset + i).collect();
        let mut with_offset = vec![0f64; rel.len()];
        let mut absolute = vec![0f64; abs.len()];
        block.sense_votes_select(&wl, offset, &rel, &ladder, 1.0, &mut with_offset);
        block.sense_votes_select(&wl, 0, &abs, &ladder, 1.0, &mut absolute);
        assert_eq!(with_offset, absolute);
    }

    #[test]
    fn select_empty_is_noop() {
        let mut block = ideal_block(4);
        block.program_string(&[1; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores: Vec<f64> = Vec::new();
        block.sense_votes_select(&[0; CELLS_PER_STRING], 0, &[], &ladder, 1.0, &mut scores);
        assert!(scores.is_empty());
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn select_beyond_programmed_panics() {
        let mut block = ideal_block(4);
        block.program_string(&[0; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), 4);
        let mut scores = vec![0f64; 1];
        block.sense_votes_select(&[0; CELLS_PER_STRING], 0, &[1], &ladder, 1.0, &mut scores);
    }

    #[test]
    fn rung_cache_tracks_ladder_changes() {
        let mut block = random_block(40, VariationModel::IDEAL, 3);
        let mut rng = Rng::new(8);
        let wl = random_wordline(&mut rng);
        for len in [4usize, 16, 8] {
            let ladder = SenseLadder::new(&McamParams::default(), len);
            let mut fused = vec![0f64; 40];
            let mut naive = vec![0f64; 40];
            block.sense_votes_range(&wl, 0, 40, &ladder, 1.0, &mut fused);
            block.sense_votes_range_naive(&wl, 0, 40, &ladder, 1.0, &mut naive);
            assert_eq!(fused, naive, "ladder depth {len}");
        }
    }

    #[test]
    fn active_kernel_matches_build_features() {
        let expected = if cfg!(feature = "simd") {
            KernelVariant::Simd
        } else {
            KernelVariant::IntegerAccum
        };
        assert_eq!(McamBlock::active_kernel(), expected);
        assert_eq!(KernelVariant::IntegerAccum.name(), "integer-accum");
    }

    #[test]
    fn range_variants_match_scalar_fused_ideal_bitwise() {
        // Ideal path consumes no RNG, so every variant — the dispatcher,
        // the explicit integer-accumulation kernel, and (under
        // `--features simd`) the SIMD kernel — can run on one block and
        // must reproduce the scalar fused oracle to the last bit.
        let variation = VariationModel { program_sigma: 0.25, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 61);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(17);
        for (first, count) in [(0, 150), (0, 1), (3, 64), (5, 129), (64, 64), (149, 1)] {
            let wl = random_wordline(&mut rng);
            let weight = rng.range_f64(0.25, 4.0);
            let mut oracle = vec![0.125f64; count];
            let mut dispatch = vec![0.125f64; count];
            let mut int = vec![0.125f64; count];
            block.sense_votes_range_scalar(&wl, first, count, &ladder, weight, &mut oracle);
            block.sense_votes_range(&wl, first, count, &ladder, weight, &mut dispatch);
            block.sense_votes_range_int(&wl, first, count, &ladder, weight, &mut int);
            assert_eq!(dispatch, oracle, "dispatch, range ({first}, {count})");
            assert_eq!(int, oracle, "int, range ({first}, {count})");
            #[cfg(feature = "simd")]
            {
                let mut simd = vec![0.125f64; count];
                block.sense_votes_range_simd(&wl, first, count, &ladder, weight, &mut simd);
                assert_eq!(simd, oracle, "simd, range ({first}, {count})");
            }
        }
    }

    #[test]
    fn range_variants_match_scalar_fused_noisy_bitwise() {
        // Under read noise every variant shares `range_noisy`, so
        // identically seeded twins must agree bit for bit AND leave
        // their RNG streams aligned across repeated calls.
        let variation = VariationModel { program_sigma: 0.15, read_sigma: 0.05 };
        let mut a = random_block(130, variation, 29);
        let mut b = random_block(130, variation, 29);
        let ladder = SenseLadder::new(&McamParams::default(), 12);
        let mut rng = Rng::new(53);
        for (first, count) in [(0, 130), (7, 65), (0, 64), (129, 1), (40, 13)] {
            let wl = random_wordline(&mut rng);
            let mut oracle = vec![0f64; count];
            let mut int = vec![0f64; count];
            a.sense_votes_range_scalar(&wl, first, count, &ladder, 1.5, &mut oracle);
            b.sense_votes_range_int(&wl, first, count, &ladder, 1.5, &mut int);
            assert_eq!(int, oracle, "range ({first}, {count})");
        }
    }

    #[test]
    fn select_variants_match_scalar_fused_ideal_bitwise() {
        let variation = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut block = random_block(150, variation, 83);
        let ladder = SenseLadder::new(&McamParams::default(), 16);
        let mut rng = Rng::new(41);
        for trial in 0..6 {
            let wl = random_wordline(&mut rng);
            let indices: Vec<usize> = (0..150).filter(|_| rng.below(2) == 0).collect();
            let weight = rng.range_f64(0.25, 4.0);
            let mut oracle = vec![0.5f64; indices.len()];
            let mut dispatch = vec![0.5f64; indices.len()];
            let mut int = vec![0.5f64; indices.len()];
            block.sense_votes_select_scalar(&wl, 0, &indices, &ladder, weight, &mut oracle);
            block.sense_votes_select(&wl, 0, &indices, &ladder, weight, &mut dispatch);
            block.sense_votes_select_int(&wl, 0, &indices, &ladder, weight, &mut int);
            assert_eq!(dispatch, oracle, "dispatch, trial {trial}");
            assert_eq!(int, oracle, "int, trial {trial}");
            #[cfg(feature = "simd")]
            {
                let mut simd = vec![0.5f64; indices.len()];
                block.sense_votes_select_simd(&wl, 0, &indices, &ladder, weight, &mut simd);
                assert_eq!(simd, oracle, "simd, trial {trial}");
            }
        }
    }

    #[test]
    fn vote_accumulator_widens_exactly_past_i16_max() {
        assert!(!vote_accumulator_widens(1));
        assert!(!vote_accumulator_widens(i16::MAX as usize));
        assert!(vote_accumulator_widens(i16::MAX as usize + 1));
    }

    #[test]
    fn vote_saturating_episode_at_i16_boundary() {
        // The deliberately vote-saturating episode: the deepest ladder
        // the narrow path accepts (i16::MAX rungs) against a
        // perfect-match string, scored with the largest production
        // accumulation weight (B4E's 4^7). The i16 tile accumulator
        // reaches exactly i16::MAX on that slot — the most votes a
        // string can earn in one call — and cannot overflow because a
        // string earns at most one vote per rung.
        let depth = i16::MAX as usize;
        assert!(!vote_accumulator_widens(depth));
        let mut block = ideal_block(2);
        let cells = [2u8; CELLS_PER_STRING];
        block.program_string(&cells);
        block.program_string(&[0u8; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), depth);
        let weight = 4f64.powi(7);
        let mut int = vec![0f64; 2];
        let mut naive = vec![0f64; 2];
        block.sense_votes_range_int(&cells, 0, 2, &ladder, weight, &mut int);
        block.sense_votes_range_naive(&cells, 0, 2, &ladder, weight, &mut naive);
        assert_eq!(int, naive);
        // i_max clears every threshold: full-ladder vote count, exact in
        // f64 (32767 * 4^7 < 2^53).
        assert_eq!(int[0], weight * depth as f64);
    }

    #[test]
    fn vote_saturating_episode_one_past_boundary_widens() {
        // One rung past i16::MAX: the tile accumulator widens to i32 and
        // the full-ladder count lands one above what i16 could hold.
        let depth = i16::MAX as usize + 1;
        assert!(vote_accumulator_widens(depth));
        let mut block = ideal_block(2);
        let cells = [2u8; CELLS_PER_STRING];
        block.program_string(&cells);
        block.program_string(&[0u8; CELLS_PER_STRING]);
        let ladder = SenseLadder::new(&McamParams::default(), depth);
        let mut int = vec![0f64; 2];
        let mut naive = vec![0f64; 2];
        block.sense_votes_range_int(&cells, 0, 2, &ladder, 1.0, &mut int);
        block.sense_votes_range_naive(&cells, 0, 2, &ladder, 1.0, &mut naive);
        assert_eq!(int, naive);
        assert_eq!(int[0], depth as f64);
    }
}
