//! A NAND-flash MCAM block: up to 128K strings × 24 MLC unit cells.
//!
//! Supports the two operations of the IMAS system [14]:
//!
//! * **program** — write 24 cell levels into a string (with program-time
//!   variation sampled per cell), and
//! * **search** — drive 24 word-line levels and read the resulting
//!   series-conductance current of selected strings.
//!
//! The search hot path is the crate's performance-critical kernel (3M
//! cell evaluations per iteration at full block occupancy); see
//! DESIGN.md §Perf for the optimization log.

use super::faults::FaultModel;
use super::variation::VariationModel;
use super::McamParams;
use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// One MCAM block.
pub struct McamBlock {
    params: McamParams,
    variation: VariationModel,
    faults: FaultModel,
    capacity: usize,
    /// Programmed cell levels, `capacity * 24`, string-major.
    levels: Vec<u8>,
    /// Program-time per-cell resistance variation factor, `capacity * 24`.
    /// (Kept separate from the levels instead of expanding per-drive
    /// resistances: 120 B/string of traffic instead of 384 B — see
    /// DESIGN.md §Perf.)
    var: Vec<f32>,
    /// 4x4 match-resistance lookup `lut[q][s]` (L1-resident).
    lut: [[f32; 4]; 4],
    programmed: usize,
    rng: Rng,
}

impl McamBlock {
    pub fn new(
        capacity: usize,
        params: McamParams,
        variation: VariationModel,
        seed: u64,
    ) -> McamBlock {
        McamBlock {
            lut: params.resistance_lut(),
            params,
            variation,
            faults: FaultModel::NONE,
            capacity,
            levels: vec![0; capacity * CELLS_PER_STRING],
            var: vec![1.0; capacity * CELLS_PER_STRING],
            programmed: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn programmed(&self) -> usize {
        self.programmed
    }

    pub fn params(&self) -> &McamParams {
        &self.params
    }

    pub fn variation(&self) -> &VariationModel {
        &self.variation
    }

    /// Erase the block (programmed count returns to zero; variation is
    /// resampled on the next program, modeling a program/erase cycle).
    pub fn erase(&mut self) {
        self.programmed = 0;
    }

    /// Set the fault-injection model applied to subsequently programmed
    /// strings (reliability ablations).
    pub fn set_faults(&mut self, faults: FaultModel) {
        self.faults = faults;
    }

    /// Program the next free string with `cells` levels. Returns the
    /// string index.
    pub fn program_string(&mut self, cells: &[u8; CELLS_PER_STRING]) -> usize {
        assert!(
            self.programmed < self.capacity,
            "MCAM block full ({} strings)",
            self.capacity
        );
        let mut cells = *cells;
        if !self.faults.is_none() {
            self.faults.corrupt_string(&mut cells, &mut self.rng);
        }
        let idx = self.programmed;
        let base = idx * CELLS_PER_STRING;
        for (l, &s) in cells.iter().enumerate() {
            assert!(s <= 3, "cell level {s} out of range");
            self.levels[base + l] = s;
            self.var[base + l] = self.variation.cell_factor(&mut self.rng);
        }
        self.programmed += 1;
        idx
    }

    /// Programmed levels of string `idx` (test/debug).
    pub fn string_levels(&self, idx: usize) -> &[u8] {
        let base = idx * CELLS_PER_STRING;
        &self.levels[base..base + CELLS_PER_STRING]
    }

    /// Ideal (noise-free) current of string `idx` under `wordline`.
    #[inline]
    pub fn string_current_ideal(&self, idx: usize, wordline: &[u8; CELLS_PER_STRING]) -> f64 {
        let base = idx * CELLS_PER_STRING;
        let levels = &self.levels[base..base + CELLS_PER_STRING];
        let var = &self.var[base..base + CELLS_PER_STRING];
        let mut series = 0f32;
        for l in 0..CELLS_PER_STRING {
            let q = wordline[l];
            debug_assert!(q <= 3);
            series += self.lut[q as usize][levels[l] as usize] * var[l];
        }
        self.params.v_bl / series as f64
    }

    /// Search: drive `wordline` and sense the strings in
    /// `[first, first + count)`, appending currents (with read noise) to
    /// `out`.
    pub fn search_range(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        count: usize,
        out: &mut Vec<f64>,
    ) {
        assert!(first + count <= self.programmed, "search beyond programmed region");
        out.reserve(count);
        let read_sigma = self.variation.read_sigma;
        for idx in first..first + count {
            let current = self.string_current_ideal(idx, wordline);
            let current = if read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            out.push(current);
        }
    }

    /// Search a strided set of strings: indices `first + k * stride` for
    /// `k in [0, count)` — the SVSS access pattern (one column of every
    /// support vector's string group).
    pub fn search_strided(
        &mut self,
        wordline: &[u8; CELLS_PER_STRING],
        first: usize,
        stride: usize,
        count: usize,
        out: &mut Vec<f64>,
    ) {
        out.reserve(count);
        for k in 0..count {
            let idx = first + k * stride;
            assert!(idx < self.programmed, "strided search beyond programmed region");
            let current = self.string_current_ideal(idx, wordline);
            let current = if self.variation.read_sigma == 0.0 {
                current
            } else {
                self.variation.read_current(current, &mut self.rng)
            };
            out.push(current);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    fn ideal_block(capacity: usize) -> McamBlock {
        McamBlock::new(capacity, McamParams::default(), VariationModel::IDEAL, 7)
    }

    #[test]
    fn perfect_match_draws_i_max() {
        let mut block = ideal_block(4);
        let cells = [2u8; CELLS_PER_STRING];
        let idx = block.program_string(&cells);
        let i = block.string_current_ideal(idx, &cells);
        assert_close(i, block.params().i_max(), 1e-9);
    }

    #[test]
    fn current_matches_series_formula() {
        let mut block = ideal_block(4);
        let mut cells = [0u8; CELLS_PER_STRING];
        cells[0] = 3;
        cells[1] = 1;
        let idx = block.program_string(&cells);
        let wordline = [0u8; CELLS_PER_STRING];
        let p = McamParams::default();
        let series = 22.0 * p.resistance(0) + p.resistance(3) + p.resistance(1);
        assert_close(
            block.string_current_ideal(idx, &wordline),
            p.v_bl / series,
            1e-9,
        );
    }

    #[test]
    fn bottleneck_ordering() {
        // Same total mismatch (6): max-3 string draws less than max-1.
        let mut block = ideal_block(4);
        let mut worst = [0u8; CELLS_PER_STRING];
        worst[0] = 3;
        worst[1] = 3;
        let mut best = [0u8; CELLS_PER_STRING];
        for c in best.iter_mut().take(6) {
            *c = 1;
        }
        let a = block.program_string(&worst);
        let b = block.program_string(&best);
        let wl = [0u8; CELLS_PER_STRING];
        assert!(block.string_current_ideal(a, &wl) < block.string_current_ideal(b, &wl));
    }

    #[test]
    fn search_range_collects_all() {
        let mut block = ideal_block(8);
        for v in 0..8u8 {
            block.program_string(&[v % 4; CELLS_PER_STRING]);
        }
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 8, &mut out);
        assert_eq!(out.len(), 8);
        // levels 0 and 4%4=0 strings draw the max current
        assert_close(out[0], 1.0, 1e-9);
        assert!(out[3] < out[2] && out[2] < out[1] && out[1] < out[0]);
    }

    #[test]
    fn search_strided_picks_columns() {
        let mut block = ideal_block(8);
        for v in 0..8u8 {
            block.program_string(&[v % 4; CELLS_PER_STRING]);
        }
        let mut strided = Vec::new();
        block.search_strided(&[0; CELLS_PER_STRING], 1, 4, 2, &mut strided);
        let mut direct = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 1, 1, &mut direct);
        block.search_range(&[0; CELLS_PER_STRING], 5, 1, &mut direct);
        assert_eq!(strided, direct);
    }

    #[test]
    #[should_panic(expected = "full")]
    fn program_beyond_capacity_panics() {
        let mut block = ideal_block(1);
        block.program_string(&[0; CELLS_PER_STRING]);
        block.program_string(&[0; CELLS_PER_STRING]);
    }

    #[test]
    #[should_panic(expected = "beyond programmed")]
    fn search_unprogrammed_panics() {
        let mut block = ideal_block(4);
        let mut out = Vec::new();
        block.search_range(&[0; CELLS_PER_STRING], 0, 1, &mut out);
    }

    #[test]
    fn erase_resets() {
        let mut block = ideal_block(2);
        block.program_string(&[1; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
        block.erase();
        assert_eq!(block.programmed(), 0);
        block.program_string(&[2; CELLS_PER_STRING]);
        assert_eq!(block.programmed(), 1);
    }

    #[test]
    fn variation_perturbs_currents() {
        let mut block = McamBlock::new(
            16,
            McamParams::default(),
            VariationModel { program_sigma: 0.2, read_sigma: 0.0 },
            9,
        );
        let cells = [1u8; CELLS_PER_STRING];
        for _ in 0..16 {
            block.program_string(&cells);
        }
        let mut out = Vec::new();
        block.search_range(&[1; CELLS_PER_STRING], 0, 16, &mut out);
        let mean = out.iter().sum::<f64>() / out.len() as f64;
        assert!(out.iter().any(|&c| (c - mean).abs() > 1e-6), "no spread");
    }
}
