//! Cycle-level simulator of the 3D NAND-flash MCAM of [14].
//!
//! The paper's evaluation runs on measured silicon; this module is the
//! documented substitution (DESIGN.md §2): a behavioural device model
//! exposing exactly the knobs the paper's claims depend on — string
//! current as a function of (total mismatch, max mismatch), per-cell
//! device variation, sense-amplifier thresholding with a voting scheme,
//! and search timing.
//!
//! * [`McamParams`] — electrical constants of the series-conductance
//!   string model (shared with the L1 Pallas kernel).
//! * [`block::McamBlock`] — a 128K-string block: program / word-line
//!   search over cell-major plane storage, sensed by the fused tiled
//!   sense→vote→accumulate kernel (`sense_votes_range`, the L3 hot
//!   path; the scalar reference is retained as the equivalence oracle).
//! * [`variation::VariationModel`] — program-time lognormal cell
//!   variation + per-read current noise (tile-batched on the hot path,
//!   same RNG draw order as scalar reads).
//! * [`sense::SenseLadder`] — multi-threshold SA sensing and voting,
//!   plus [`sense::SeriesRungs`] — the ladder translated into exact
//!   series-resistance rungs for the division-free ideal sense path.
//! * [`timing::SearchTiming`] — per-iteration latency (Table 2's
//!   throughput arithmetic).

pub mod block;
pub mod faults;
pub mod sense;
pub mod timing;
pub mod variation;

use crate::CELLS_PER_STRING;

/// Electrical constants of the string-current model. Defaults match the
/// python side (`McamParams` in `kernels/mcam_search.py`): a unit cell at
/// mismatch `m` contributes resistance `r0 * alpha^m`; the string current
/// is `v_bl / Σ r_i`, which yields both the total-mismatch dependence and
/// the bottleneck effect of Figs. 2(b)/(c).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct McamParams {
    pub r0: f64,
    pub alpha: f64,
    pub v_bl: f64,
}

impl Default for McamParams {
    fn default() -> Self {
        McamParams { r0: 1.0, alpha: 6.0, v_bl: 24.0 }
    }
}

impl McamParams {
    /// Resistance of a unit cell at mismatch level `m`.
    pub fn resistance(&self, mismatch: u8) -> f64 {
        debug_assert!(mismatch <= 3);
        self.r0 * self.alpha.powi(mismatch as i32)
    }

    /// Current of an all-match string (the feasible maximum).
    pub fn i_max(&self) -> f64 {
        self.v_bl / (CELLS_PER_STRING as f64 * self.r0)
    }

    /// Current of an all-mismatch-3 string (the feasible minimum).
    pub fn i_min(&self) -> f64 {
        self.v_bl / (CELLS_PER_STRING as f64 * self.r0 * self.alpha.powi(3))
    }

    /// 4×4 lookup `resistance(|q - s|)` for the search hot path.
    pub fn resistance_lut(&self) -> [[f32; 4]; 4] {
        let mut lut = [[0f32; 4]; 4];
        for (q, row) in lut.iter_mut().enumerate() {
            for (s, r) in row.iter_mut().enumerate() {
                *r = self.resistance((q as i32 - s as i32).unsigned_abs() as u8) as f32;
            }
        }
        lut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn default_current_bounds() {
        let p = McamParams::default();
        assert_close(p.i_max(), 1.0, 1e-12);
        assert_close(p.i_min(), 1.0 / 216.0, 1e-12);
    }

    #[test]
    fn resistance_monotone() {
        let p = McamParams::default();
        for m in 0..3u8 {
            assert!(p.resistance(m) < p.resistance(m + 1));
        }
    }

    #[test]
    fn lut_matches_direct() {
        let p = McamParams { r0: 0.5, alpha: 4.0, v_bl: 10.0 };
        let lut = p.resistance_lut();
        for q in 0..4usize {
            for s in 0..4usize {
                let m = (q as i32 - s as i32).unsigned_abs() as u8;
                assert_close(lut[q][s] as f64, p.resistance(m), 1e-6);
            }
        }
    }
}
