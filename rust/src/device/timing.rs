//! Search timing model behind Table 2's throughput column.
//!
//! A single constant — 50 µs per search iteration — reproduces all four
//! of the paper's throughput entries exactly (DESIGN.md §2):
//!
//! | dataset  | mode | iterations | 1 / (it × 50 µs) | paper      |
//! |----------|------|-----------:|-----------------:|-----------:|
//! | Omniglot | SVSS |         64 |        312.5 s⁻¹ | 312.5 s⁻¹  |
//! | Omniglot | AVSS |          2 |       10 000 s⁻¹ | 10 000 s⁻¹ |
//! | CUB      | SVSS |        500 |           40 s⁻¹ | 40 s⁻¹     |
//! | CUB      | AVSS |         20 |        1 000 s⁻¹ | 1 000 s⁻¹  |

/// Microseconds per MCAM search iteration (word-line setup + sensing).
pub const SEARCH_ITERATION_US: f64 = 50.0;

/// Timing accounting for one or more searches.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SearchTiming {
    pub iterations: u64,
}

impl SearchTiming {
    pub fn add_iterations(&mut self, n: u64) {
        self.iterations += n;
    }

    /// Simulated latency of the accumulated iterations, in microseconds.
    pub fn latency_us(&self) -> f64 {
        self.iterations as f64 * SEARCH_ITERATION_US
    }

    /// Searches per second at `iterations_per_search`.
    pub fn throughput_per_s(iterations_per_search: u64) -> f64 {
        1e6 / (iterations_per_search as f64 * SEARCH_ITERATION_US)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn reproduces_table2_throughputs() {
        assert_close(SearchTiming::throughput_per_s(64), 312.5, 1e-12);
        assert_close(SearchTiming::throughput_per_s(2), 10_000.0, 1e-12);
        assert_close(SearchTiming::throughput_per_s(500), 40.0, 1e-12);
        assert_close(SearchTiming::throughput_per_s(20), 1_000.0, 1e-12);
    }

    #[test]
    fn latency_accumulates() {
        let mut t = SearchTiming::default();
        t.add_iterations(2);
        t.add_iterations(3);
        assert_close(t.latency_us(), 250.0, 1e-12);
    }
}
