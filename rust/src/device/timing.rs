//! Search timing model behind Table 2's throughput column.
//!
//! A single constant — 50 µs per search iteration — reproduces all four
//! of the paper's throughput entries exactly (DESIGN.md §2):
//!
//! | dataset  | mode | iterations | 1 / (it × 50 µs) | paper      |
//! |----------|------|-----------:|-----------------:|-----------:|
//! | Omniglot | SVSS |         64 |        312.5 s⁻¹ | 312.5 s⁻¹  |
//! | Omniglot | AVSS |          2 |       10 000 s⁻¹ | 10 000 s⁻¹ |
//! | CUB      | SVSS |        500 |           40 s⁻¹ | 40 s⁻¹     |
//! | CUB      | AVSS |         20 |        1 000 s⁻¹ | 1 000 s⁻¹  |

/// Microseconds per MCAM search iteration (word-line setup + sensing).
pub const SEARCH_ITERATION_US: f64 = 50.0;

/// Timing accounting for one or more searches.
///
/// Honest-accounting contract (DESIGN.md §Cascade): `iterations` counts
/// only word-line applications **actually executed** — per-request mode
/// overrides, cascade early exits and budget stops all shrink it. The
/// configured-mode full-scan count is an upper bound, available as
/// `BackendStats::max_iterations_per_search`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SearchTiming {
    /// Word-line iterations executed so far.
    pub iterations: u64,
    /// Searches completed so far.
    pub searches: u64,
}

impl SearchTiming {
    pub fn add_iterations(&mut self, n: u64) {
        self.iterations += n;
    }

    /// Record one completed search (pairs with the iterations it added).
    pub fn finish_search(&mut self) {
        self.searches += 1;
    }

    /// Simulated latency of the accumulated iterations, in microseconds.
    pub fn latency_us(&self) -> f64 {
        self.iterations as f64 * SEARCH_ITERATION_US
    }

    /// Mean iterations actually executed per completed search (0.0
    /// before the first search).
    pub fn avg_iterations_per_search(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.iterations as f64 / self.searches as f64
        }
    }

    /// Searches per second at `iterations_per_search`.
    pub fn throughput_per_s(iterations_per_search: u64) -> f64 {
        1e6 / (iterations_per_search as f64 * SEARCH_ITERATION_US)
    }

    /// Searches per second at a (possibly fractional) measured average
    /// iteration count — the cascade-honest companion of
    /// [`Self::throughput_per_s`]. Returns 0.0 for a zero average.
    pub fn throughput_per_s_avg(avg_iterations: f64) -> f64 {
        if avg_iterations <= 0.0 {
            0.0
        } else {
            1e6 / (avg_iterations * SEARCH_ITERATION_US)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn reproduces_table2_throughputs() {
        assert_close(SearchTiming::throughput_per_s(64), 312.5, 1e-12);
        assert_close(SearchTiming::throughput_per_s(2), 10_000.0, 1e-12);
        assert_close(SearchTiming::throughput_per_s(500), 40.0, 1e-12);
        assert_close(SearchTiming::throughput_per_s(20), 1_000.0, 1e-12);
    }

    #[test]
    fn latency_accumulates() {
        let mut t = SearchTiming::default();
        t.add_iterations(2);
        t.add_iterations(3);
        assert_close(t.latency_us(), 250.0, 1e-12);
    }

    #[test]
    fn avg_tracks_actual_iterations() {
        let mut t = SearchTiming::default();
        assert_eq!(t.avg_iterations_per_search(), 0.0);
        // one AVSS search (2 iterations) + one SVSS override (64)
        t.add_iterations(2);
        t.finish_search();
        t.add_iterations(64);
        t.finish_search();
        assert_eq!(t.searches, 2);
        assert_close(t.avg_iterations_per_search(), 33.0, 1e-12);
        assert_close(
            SearchTiming::throughput_per_s_avg(33.0),
            1e6 / (33.0 * 50.0),
            1e-12,
        );
        assert_eq!(SearchTiming::throughput_per_s_avg(0.0), 0.0);
    }
}
