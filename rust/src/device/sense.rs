//! Sense amplifier + voting scheme (§2.2 of the paper).
//!
//! Instead of measuring exact analog currents, the IMAS system senses each
//! string against a ladder of current thresholds; the number of thresholds
//! a string clears is its *vote count* for that iteration. Votes
//! accumulate across iterations (weighted per Eq. 2 for B4E) and the
//! support vector with the most votes wins.
//!
//! The ladder is log-spaced across the feasible current range
//! `[i_min, i_max]` with midpoints `(t + 0.5) / T` — identical to
//! `sa_thresholds` in `python/compile/mcam_sim.py`.

use super::McamParams;

/// A descending-capability SA threshold ladder.
#[derive(Debug, Clone)]
pub struct SenseLadder {
    thresholds: Vec<f64>,
}

impl SenseLadder {
    /// Build a `n`-threshold log-spaced ladder for `params`.
    pub fn new(params: &McamParams, n: usize) -> SenseLadder {
        assert!(n >= 1, "ladder needs at least one threshold");
        let lo = params.i_min().ln();
        let hi = params.i_max().ln();
        let thresholds = (0..n)
            .map(|t| {
                let frac = (t as f64 + 0.5) / n as f64;
                (lo + (hi - lo) * frac).exp()
            })
            .collect();
        SenseLadder { thresholds }
    }

    pub fn len(&self) -> usize {
        self.thresholds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.thresholds.is_empty()
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    /// Vote count of a sensed current: thresholds strictly below it.
    pub fn votes(&self, current: f64) -> u32 {
        // The ladder is sorted ascending → binary search would work, but
        // with <= 32 thresholds a linear scan is faster and branch-
        // predictable; see DESIGN.md §Perf.
        let mut votes = 0;
        for &t in &self.thresholds {
            if current > t {
                votes += 1;
            } else {
                break;
            }
        }
        votes
    }

    /// Votes for a batch of currents (hot-path helper).
    pub fn votes_batch(&self, currents: &[f64], out: &mut Vec<u32>) {
        out.reserve(currents.len());
        for &c in currents {
            out.push(self.votes(c));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn ladder(n: usize) -> SenseLadder {
        SenseLadder::new(&McamParams::default(), n)
    }

    #[test]
    fn ladder_is_sorted_and_in_range() {
        let p = McamParams::default();
        let l = ladder(16);
        assert_eq!(l.len(), 16);
        for w in l.thresholds().windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(l.thresholds()[0] > p.i_min());
        assert!(l.thresholds()[15] < p.i_max());
    }

    #[test]
    fn votes_monotone_in_current() {
        let l = ladder(16);
        let p = McamParams::default();
        let mut last = 0;
        let mut c = p.i_min();
        while c < p.i_max() {
            let v = l.votes(c);
            assert!(v >= last);
            last = v;
            c *= 1.2;
        }
    }

    #[test]
    fn extremes() {
        let l = ladder(16);
        let p = McamParams::default();
        assert_eq!(l.votes(p.i_min()), 0);
        assert_eq!(l.votes(p.i_max()), 16);
        assert_eq!(l.votes(0.0), 0);
    }

    #[test]
    fn matches_python_formula() {
        // thr_t = exp(lo + (hi - lo) * (t + 0.5) / T)
        let p = McamParams::default();
        let l = ladder(8);
        let (lo, hi) = (p.i_min().ln(), p.i_max().ln());
        for (t, &thr) in l.thresholds().iter().enumerate() {
            let want = (lo + (hi - lo) * (t as f64 + 0.5) / 8.0).exp();
            assert!((thr - want).abs() < 1e-12);
        }
    }

    #[test]
    fn votes_equal_linear_count() {
        let l = ladder(16);
        forall(
            "votes == #thresholds below",
            256,
            |rng| rng.range_f64(0.0, 1.2),
            |&c| {
                let direct = l.thresholds().iter().filter(|&&t| c > t).count() as u32;
                l.votes(c) == direct
            },
        );
    }

    #[test]
    fn batch_matches_scalar() {
        let l = ladder(12);
        let currents = [0.001, 0.01, 0.1, 0.5, 1.0];
        let mut out = Vec::new();
        l.votes_batch(&currents, &mut out);
        let scalar: Vec<u32> = currents.iter().map(|&c| l.votes(c)).collect();
        assert_eq!(out, scalar);
    }
}
