//! Sense amplifier + voting scheme (§2.2 of the paper).
//!
//! Instead of measuring exact analog currents, the IMAS system senses each
//! string against a ladder of current thresholds; the number of thresholds
//! a string clears is its *vote count* for that iteration. Votes
//! accumulate across iterations (weighted per Eq. 2 for B4E) and the
//! support vector with the most votes wins.
//!
//! The ladder is log-spaced across the feasible current range
//! `[i_min, i_max]` with midpoints `(t + 0.5) / T` — identical to
//! `sa_thresholds` in `python/compile/mcam_sim.py`.
//!
//! For the fused sense kernel's ideal (noise-free) path the ladder can be
//! translated into the **series-resistance domain** ([`SeriesRungs`]):
//! comparing the f32 series sum against precomputed rungs decides exactly
//! the same votes as comparing the ideal current `v_bl / series` against
//! the thresholds, while eliminating the per-string division.

use super::McamParams;

/// A descending-capability SA threshold ladder.
#[derive(Debug, Clone)]
pub struct SenseLadder {
    thresholds: Vec<f64>,
}

impl SenseLadder {
    /// Build a `n`-threshold log-spaced ladder for `params`.
    pub fn new(params: &McamParams, n: usize) -> SenseLadder {
        assert!(n >= 1, "ladder needs at least one threshold");
        let lo = params.i_min().ln();
        let hi = params.i_max().ln();
        let thresholds = (0..n)
            .map(|t| {
                let frac = (t as f64 + 0.5) / n as f64;
                (lo + (hi - lo) * frac).exp()
            })
            .collect();
        SenseLadder { thresholds }
    }

    pub fn len(&self) -> usize {
        self.thresholds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.thresholds.is_empty()
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.thresholds
    }

    /// Vote count of a sensed current: thresholds strictly below it.
    pub fn votes(&self, current: f64) -> u32 {
        // The ladder is sorted ascending → binary search would work, but
        // with <= 32 thresholds a linear scan is faster and branch-
        // predictable; see DESIGN.md §Perf.
        let mut votes = 0;
        for &t in &self.thresholds {
            if current > t {
                votes += 1;
            } else {
                break;
            }
        }
        votes
    }

    /// Votes for a batch of currents. The noisy path of the fused sense
    /// kernel ([`crate::device::block::McamBlock::sense_votes_range`])
    /// routes every sensed tile through this helper; the ideal path
    /// votes in the series domain via [`SeriesRungs`] instead (decision
    /// recorded in DESIGN.md §Perf).
    pub fn votes_batch(&self, currents: &[f64], out: &mut Vec<u32>) {
        out.reserve(currents.len());
        for &c in currents {
            out.push(self.votes(c));
        }
    }

    /// Translate the ladder into exact series-resistance rungs for
    /// bit-line voltage `v_bl` — the fused kernel's division-free ideal
    /// path. Rebuilding costs ~31 f64 divisions per threshold, so
    /// callers on the hot path cache the result (the block invalidates
    /// its cache by exact threshold comparison).
    pub fn series_rungs(&self, v_bl: f64) -> SeriesRungs {
        let rungs = self.thresholds.iter().map(|&thr| exact_series_rung(v_bl, thr)).collect();
        SeriesRungs { rungs }
    }
}

/// The SA threshold ladder translated into the series-resistance domain
/// for the ideal (noise-free) fused sense kernel: a string with f32
/// series sum `s` draws ideal current `v_bl / s`, and
///
/// ```text
/// v_bl / (s as f64) > thresholds[t]   ⟺   s <= rungs[t]
/// ```
///
/// where `rungs[t]` is the **largest** f32 series sum that still clears
/// threshold `t`. The rungs are found by exact bit-space bisection, so
/// the equivalence holds for every representable series sum — votes stay
/// bit-identical to the current-domain compare while the per-string
/// division disappears. Ascending current thresholds give descending
/// rungs.
#[derive(Debug, Clone, Default)]
pub struct SeriesRungs {
    rungs: Vec<f32>,
}

impl SeriesRungs {
    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rungs.is_empty()
    }

    pub fn rungs(&self) -> &[f32] {
        &self.rungs
    }

    /// Vote count of a string with f32 series-resistance sum `series`:
    /// rungs at or above it. Mirrors [`SenseLadder::votes`] — the rungs
    /// descend, so the linear scan breaks at the first miss.
    #[inline]
    pub fn votes_for_series(&self, series: f32) -> u32 {
        let mut votes = 0;
        for &r in &self.rungs {
            if series <= r {
                votes += 1;
            } else {
                break;
            }
        }
        votes
    }

    /// Branchless twin of [`Self::votes_for_series`]: count **every**
    /// cleared rung instead of breaking at the first miss. The rungs are
    /// non-increasing, so `series <= rungs[t]` holds on a prefix of the
    /// ladder — if rung `t` misses, every later (smaller-or-equal) rung
    /// misses too — and the full count equals the break-loop count for
    /// every input, NaN included (`series <= r` is false, both paths
    /// count zero). This is the counting scheme of the integer-vote tile
    /// accumulators in [`crate::device::block::McamBlock`]: with no
    /// data-dependent branch the loop vectorizes, at the cost of always
    /// walking the whole ladder.
    #[inline]
    pub fn votes_for_series_dense(&self, series: f32) -> u32 {
        let mut votes = 0u32;
        for &r in &self.rungs {
            votes += (series <= r) as u32;
        }
        votes
    }
}

/// Largest non-negative f32 `s` for which the ideal current `v_bl / s`
/// still clears `thr` under the exact hot-path predicate
/// `v_bl / (s as f64) > thr`. Non-negative f32 values are ordered by
/// their bit patterns and the predicate is monotone non-increasing in
/// `s` (f32→f64 widening and IEEE f64 division are both monotone), so
/// the boundary is found by bisection over bit space.
fn exact_series_rung(v_bl: f64, thr: f64) -> f32 {
    let clears = |bits: u32| v_bl / f32::from_bits(bits) as f64 > thr;
    if !clears(0) {
        // +0.0 draws infinite ideal current; if even that misses the
        // threshold (thr = +inf), no series sum can clear it.
        return 0.0;
    }
    let (mut lo, mut hi) = (0u32, f32::MAX.to_bits());
    while lo < hi {
        let mid = lo + (hi - lo).div_ceil(2);
        if clears(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    f32::from_bits(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn ladder(n: usize) -> SenseLadder {
        SenseLadder::new(&McamParams::default(), n)
    }

    #[test]
    fn ladder_is_sorted_and_in_range() {
        let p = McamParams::default();
        let l = ladder(16);
        assert_eq!(l.len(), 16);
        for w in l.thresholds().windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(l.thresholds()[0] > p.i_min());
        assert!(l.thresholds()[15] < p.i_max());
    }

    #[test]
    fn votes_monotone_in_current() {
        let l = ladder(16);
        let p = McamParams::default();
        let mut last = 0;
        let mut c = p.i_min();
        while c < p.i_max() {
            let v = l.votes(c);
            assert!(v >= last);
            last = v;
            c *= 1.2;
        }
    }

    #[test]
    fn extremes() {
        let l = ladder(16);
        let p = McamParams::default();
        assert_eq!(l.votes(p.i_min()), 0);
        assert_eq!(l.votes(p.i_max()), 16);
        assert_eq!(l.votes(0.0), 0);
    }

    #[test]
    fn matches_python_formula() {
        // thr_t = exp(lo + (hi - lo) * (t + 0.5) / T)
        let p = McamParams::default();
        let l = ladder(8);
        let (lo, hi) = (p.i_min().ln(), p.i_max().ln());
        for (t, &thr) in l.thresholds().iter().enumerate() {
            let want = (lo + (hi - lo) * (t as f64 + 0.5) / 8.0).exp();
            assert!((thr - want).abs() < 1e-12);
        }
    }

    #[test]
    fn votes_equal_linear_count() {
        let l = ladder(16);
        forall(
            "votes == #thresholds below",
            256,
            |rng| rng.range_f64(0.0, 1.2),
            |&c| {
                let direct = l.thresholds().iter().filter(|&&t| c > t).count() as u32;
                l.votes(c) == direct
            },
        );
    }

    #[test]
    fn batch_matches_scalar() {
        let l = ladder(12);
        let currents = [0.001, 0.01, 0.1, 0.5, 1.0];
        let mut out = Vec::new();
        l.votes_batch(&currents, &mut out);
        let scalar: Vec<u32> = currents.iter().map(|&c| l.votes(c)).collect();
        assert_eq!(out, scalar);
    }

    #[test]
    fn series_rungs_are_exact_boundaries() {
        let p = McamParams::default();
        let l = ladder(16);
        let rungs = l.series_rungs(p.v_bl);
        assert_eq!(rungs.len(), 16);
        assert!(!rungs.is_empty());
        for (&thr, &rung) in l.thresholds().iter().zip(rungs.rungs()) {
            assert!(p.v_bl / rung as f64 > thr, "rung must clear its threshold");
            let above = f32::from_bits(rung.to_bits() + 1);
            assert!(p.v_bl / above as f64 <= thr, "rung + 1 ulp must miss");
        }
        for w in rungs.rungs().windows(2) {
            assert!(w[0] >= w[1], "rungs must descend");
        }
    }

    #[test]
    fn exact_series_rung_boundary_forall() {
        forall(
            "rung is the largest clearing f32",
            256,
            |rng| (rng.range_f64(0.5, 100.0), rng.range_f64(1e-6, 50.0)),
            |&(v_bl, thr)| {
                let rung = exact_series_rung(v_bl, thr);
                let clears = |s: f32| v_bl / s as f64 > thr;
                let above = f32::from_bits(rung.to_bits() + 1);
                clears(rung) && !clears(above)
            },
        );
    }

    #[test]
    fn series_votes_match_current_votes() {
        // The fused kernel's correctness hinge, probed adversarially:
        // random series sums plus values within a few ULPs of every rung.
        let p = McamParams::default();
        let l = ladder(16);
        let rungs = l.series_rungs(p.v_bl);
        forall(
            "series-domain votes == current-domain votes",
            512,
            |rng| {
                if rng.below(2) == 0 {
                    rng.range_f64(20.0, 6000.0) as f32
                } else {
                    let r = rungs.rungs()[rng.below(16)];
                    let offset = rng.below(7) as i64 - 3;
                    f32::from_bits((r.to_bits() as i64 + offset) as u32)
                }
            },
            |&s| {
                let current = p.v_bl / s as f64;
                rungs.votes_for_series(s) == l.votes(current)
            },
        );
    }

    #[test]
    fn dense_votes_equal_break_loop_votes() {
        // The prefix property the integer-vote kernels lean on, probed
        // adversarially: random series sums plus values within a few
        // ULPs of every rung (where a non-monotone ladder would betray
        // the full count first).
        let p = McamParams::default();
        let l = ladder(16);
        let rungs = l.series_rungs(p.v_bl);
        forall(
            "dense rung count == break-loop rung count",
            512,
            |rng| {
                if rng.below(2) == 0 {
                    rng.range_f64(20.0, 6000.0) as f32
                } else {
                    let r = rungs.rungs()[rng.below(16)];
                    let offset = rng.below(7) as i64 - 3;
                    f32::from_bits((r.to_bits() as i64 + offset) as u32)
                }
            },
            |&s| rungs.votes_for_series_dense(s) == rungs.votes_for_series(s),
        );
        // NaN: both schemes count zero (every compare is false).
        assert_eq!(rungs.votes_for_series(f32::NAN), 0);
        assert_eq!(rungs.votes_for_series_dense(f32::NAN), 0);
    }

    #[test]
    fn degenerate_rungs() {
        // thr = +inf: nothing clears, so the rung pins to 0 and a
        // positive series sum never votes.
        assert_eq!(exact_series_rung(24.0, f64::INFINITY), 0.0);
        // thr <= 0: every finite series sum clears.
        let rung = exact_series_rung(24.0, 0.0);
        assert_eq!(rung, f32::MAX);
    }
}
