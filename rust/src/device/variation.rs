//! Device non-idealities (§2.2 / Fig. 2(b) of the paper): threshold-voltage
//! spread from fabrication + program operations, modeled as a lognormal
//! multiplicative factor on each cell's resistance (fixed at program
//! time), plus optional per-read current noise (sensing noise).
//!
//! Determinism contract: this model holds no RNG of its own — every
//! sample is drawn from the caller-provided [`Rng`], which each
//! [`crate::device::block::McamBlock`] seeds from `EngineConfig::with_seed`
//! via [`crate::testutil::derive_seed`] (one decorrelated stream per
//! shard/replica). A fixed seed therefore replays program variation and
//! read noise bit-for-bit; `rust/tests/test_determinism.rs` pins this.

use crate::testutil::Rng;

/// Variation knobs. `sigma = 0` disables a component entirely, making the
/// device bit-exact against the python reference (cross-layer testvecs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariationModel {
    /// Lognormal sigma of the per-cell resistance factor (program-time).
    pub program_sigma: f64,
    /// Lognormal sigma applied to the string current at each read.
    pub read_sigma: f64,
}

impl VariationModel {
    pub const IDEAL: VariationModel = VariationModel { program_sigma: 0.0, read_sigma: 0.0 };

    /// Default calibrated so the ideal-vs-noisy accuracy gap lands in the
    /// few-percent range the paper reports (>3.67% loss on Omniglot).
    pub fn nand_default() -> VariationModel {
        VariationModel { program_sigma: 0.15, read_sigma: 0.05 }
    }

    pub fn is_ideal(&self) -> bool {
        self.program_sigma == 0.0 && self.read_sigma == 0.0
    }

    /// Sample a program-time resistance factor for one cell.
    pub fn cell_factor(&self, rng: &mut Rng) -> f32 {
        if self.program_sigma == 0.0 {
            1.0
        } else {
            (self.program_sigma * rng.gaussian()).exp() as f32
        }
    }

    /// Apply read noise to a sensed current.
    pub fn read_current(&self, current: f64, rng: &mut Rng) -> f64 {
        if self.read_sigma == 0.0 {
            current
        } else {
            current * (self.read_sigma * rng.gaussian()).exp()
        }
    }

    /// Apply read noise to a slice of sensed currents in place, drawing
    /// one Gaussian per current in slice order — the tile-granular fast
    /// path of the fused sense kernel
    /// ([`crate::device::block::McamBlock::sense_votes_range`]).
    /// Consumes the RNG in exactly the same order as per-string
    /// [`Self::read_current`] calls, so tiled and scalar sensing replay
    /// bit-for-bit (the determinism contract above).
    pub fn read_currents(&self, currents: &mut [f64], rng: &mut Rng) {
        if self.read_sigma == 0.0 {
            return;
        }
        for current in currents.iter_mut() {
            *current *= (self.read_sigma * rng.gaussian()).exp();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_identity() {
        let mut rng = Rng::new(1);
        assert_eq!(VariationModel::IDEAL.cell_factor(&mut rng), 1.0);
        assert_eq!(VariationModel::IDEAL.read_current(0.5, &mut rng), 0.5);
        assert!(VariationModel::IDEAL.is_ideal());
    }

    #[test]
    fn lognormal_factor_statistics() {
        let v = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut rng = Rng::new(2);
        let n = 20_000;
        let lns: Vec<f64> = (0..n).map(|_| (v.cell_factor(&mut rng) as f64).ln()).collect();
        let mean = lns.iter().sum::<f64>() / n as f64;
        let var = lns.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "ln-mean {mean}");
        assert!((var.sqrt() - 0.2).abs() < 0.01, "ln-sigma {}", var.sqrt());
    }

    #[test]
    fn read_noise_perturbs() {
        let v = VariationModel::nand_default();
        let mut rng = Rng::new(3);
        let a = v.read_current(0.5, &mut rng);
        let b = v.read_current(0.5, &mut rng);
        assert_ne!(a, b);
        assert!(a > 0.0 && b > 0.0);
    }

    #[test]
    fn batched_read_noise_matches_scalar_draws() {
        // Same seed, same draw order: the tile fast path must replay the
        // per-string scalar path bit-for-bit.
        let v = VariationModel { program_sigma: 0.0, read_sigma: 0.07 };
        let base: Vec<f64> = (1..=9).map(|i| i as f64 / 10.0).collect();
        let mut batched = base.clone();
        let mut r1 = Rng::new(42);
        v.read_currents(&mut batched, &mut r1);
        let mut r2 = Rng::new(42);
        let scalar: Vec<f64> = base.iter().map(|&c| v.read_current(c, &mut r2)).collect();
        assert_eq!(batched, scalar);
        // both consumed identical RNG state
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn ideal_batched_noise_is_noop_and_draws_nothing() {
        let mut currents = vec![0.25, 0.5];
        let mut rng = Rng::new(1);
        let mut snapshot = rng.clone();
        VariationModel::IDEAL.read_currents(&mut currents, &mut rng);
        assert_eq!(currents, vec![0.25, 0.5]);
        assert_eq!(rng.next_u64(), snapshot.next_u64());
    }
}
