//! Device non-idealities (§2.2 / Fig. 2(b) of the paper): threshold-voltage
//! spread from fabrication + program operations, modeled as a lognormal
//! multiplicative factor on each cell's resistance (fixed at program
//! time), plus optional per-read current noise (sensing noise).
//!
//! Determinism contract: this model holds no RNG of its own — every
//! sample is drawn from the caller-provided [`Rng`], which each
//! [`crate::device::block::McamBlock`] seeds from `EngineConfig::with_seed`
//! via [`crate::testutil::derive_seed`] (one decorrelated stream per
//! shard/replica). A fixed seed therefore replays program variation and
//! read noise bit-for-bit; `rust/tests/test_determinism.rs` pins this.

use crate::testutil::Rng;

/// Variation knobs. `sigma = 0` disables a component entirely, making the
/// device bit-exact against the python reference (cross-layer testvecs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariationModel {
    /// Lognormal sigma of the per-cell resistance factor (program-time).
    pub program_sigma: f64,
    /// Lognormal sigma applied to the string current at each read.
    pub read_sigma: f64,
}

impl VariationModel {
    pub const IDEAL: VariationModel = VariationModel { program_sigma: 0.0, read_sigma: 0.0 };

    /// Default calibrated so the ideal-vs-noisy accuracy gap lands in the
    /// few-percent range the paper reports (>3.67% loss on Omniglot).
    pub fn nand_default() -> VariationModel {
        VariationModel { program_sigma: 0.15, read_sigma: 0.05 }
    }

    pub fn is_ideal(&self) -> bool {
        self.program_sigma == 0.0 && self.read_sigma == 0.0
    }

    /// Sample a program-time resistance factor for one cell.
    pub fn cell_factor(&self, rng: &mut Rng) -> f32 {
        if self.program_sigma == 0.0 {
            1.0
        } else {
            (self.program_sigma * rng.gaussian()).exp() as f32
        }
    }

    /// Apply read noise to a sensed current.
    pub fn read_current(&self, current: f64, rng: &mut Rng) -> f64 {
        if self.read_sigma == 0.0 {
            current
        } else {
            current * (self.read_sigma * rng.gaussian()).exp()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_identity() {
        let mut rng = Rng::new(1);
        assert_eq!(VariationModel::IDEAL.cell_factor(&mut rng), 1.0);
        assert_eq!(VariationModel::IDEAL.read_current(0.5, &mut rng), 0.5);
        assert!(VariationModel::IDEAL.is_ideal());
    }

    #[test]
    fn lognormal_factor_statistics() {
        let v = VariationModel { program_sigma: 0.2, read_sigma: 0.0 };
        let mut rng = Rng::new(2);
        let n = 20_000;
        let lns: Vec<f64> = (0..n).map(|_| (v.cell_factor(&mut rng) as f64).ln()).collect();
        let mean = lns.iter().sum::<f64>() / n as f64;
        let var = lns.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "ln-mean {mean}");
        assert!((var.sqrt() - 0.2).abs() < 0.01, "ln-sigma {}", var.sqrt());
    }

    #[test]
    fn read_noise_perturbs() {
        let v = VariationModel::nand_default();
        let mut rng = Rng::new(3);
        let a = v.read_current(0.5, &mut rng);
        let b = v.read_current(0.5, &mut rng);
        assert_ne!(a, b);
        assert!(a > 0.0 && b > 0.0);
    }
}
