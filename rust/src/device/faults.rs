//! Failure injection: NAND reliability effects beyond Gaussian variation
//! (§2.3's "non-ideal effects", extended per [16, 17] — retention loss,
//! stuck cells, read disturb). Used by the ablation experiments to probe
//! how far each encoding's reliability margin stretches.

use crate::testutil::Rng;
use crate::CELLS_PER_STRING;

/// A fault model applied to programmed cell levels at read time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Probability a cell is stuck at level 0 (erase-state defect).
    pub stuck_low: f64,
    /// Probability a cell is stuck at level 3 (program-state defect).
    pub stuck_high: f64,
    /// Probability a cell drifts one level toward 0 (retention loss).
    pub retention_drift: f64,
}

impl FaultModel {
    pub const NONE: FaultModel =
        FaultModel { stuck_low: 0.0, stuck_high: 0.0, retention_drift: 0.0 };

    /// Mild end-of-life profile.
    pub fn worn() -> FaultModel {
        FaultModel { stuck_low: 0.002, stuck_high: 0.002, retention_drift: 0.02 }
    }

    pub fn is_none(&self) -> bool {
        *self == Self::NONE
    }

    /// Apply the model to a string's programmed levels (in place).
    /// Returns the number of corrupted cells.
    pub fn corrupt_string(&self, cells: &mut [u8; CELLS_PER_STRING], rng: &mut Rng) -> usize {
        if self.is_none() {
            return 0;
        }
        let mut corrupted = 0;
        for cell in cells.iter_mut() {
            let u = rng.next_f64();
            if u < self.stuck_low {
                if *cell != 0 {
                    corrupted += 1;
                }
                *cell = 0;
            } else if u < self.stuck_low + self.stuck_high {
                if *cell != 3 {
                    corrupted += 1;
                }
                *cell = 3;
            } else if u < self.stuck_low + self.stuck_high + self.retention_drift && *cell > 0 {
                *cell -= 1;
                corrupted += 1;
            }
        }
        corrupted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let mut rng = Rng::new(1);
        let mut cells = [2u8; CELLS_PER_STRING];
        assert_eq!(FaultModel::NONE.corrupt_string(&mut cells, &mut rng), 0);
        assert_eq!(cells, [2u8; CELLS_PER_STRING]);
    }

    #[test]
    fn stuck_low_zeroes_cells() {
        let model = FaultModel { stuck_low: 1.0, stuck_high: 0.0, retention_drift: 0.0 };
        let mut rng = Rng::new(2);
        let mut cells = [3u8; CELLS_PER_STRING];
        let n = model.corrupt_string(&mut cells, &mut rng);
        assert_eq!(n, CELLS_PER_STRING);
        assert_eq!(cells, [0u8; CELLS_PER_STRING]);
    }

    #[test]
    fn retention_drifts_one_level_down() {
        let model = FaultModel { stuck_low: 0.0, stuck_high: 0.0, retention_drift: 1.0 };
        let mut rng = Rng::new(3);
        let mut cells = [2u8; CELLS_PER_STRING];
        model.corrupt_string(&mut cells, &mut rng);
        assert_eq!(cells, [1u8; CELLS_PER_STRING]);
        // level-0 cells cannot drift below 0
        let mut zeros = [0u8; CELLS_PER_STRING];
        assert_eq!(model.corrupt_string(&mut zeros, &mut rng), 0);
        assert_eq!(zeros, [0u8; CELLS_PER_STRING]);
    }

    #[test]
    fn corruption_rate_tracks_probability() {
        let model = FaultModel { stuck_low: 0.05, stuck_high: 0.0, retention_drift: 0.0 };
        let mut rng = Rng::new(4);
        let mut total = 0usize;
        let trials = 2000;
        for _ in 0..trials {
            let mut cells = [1u8; CELLS_PER_STRING];
            total += model.corrupt_string(&mut cells, &mut rng);
        }
        let rate = total as f64 / (trials * CELLS_PER_STRING) as f64;
        assert!((rate - 0.05).abs() < 0.01, "rate {rate}");
    }
}
