//! Failure injection: NAND reliability effects beyond Gaussian variation
//! (§2.3's "non-ideal effects", extended per [16, 17] — retention loss,
//! stuck cells, read disturb).
//!
//! Two layers live here (DESIGN.md §Reliability):
//!
//! * [`FaultModel`] — the rate parameters (validated at the API edge:
//!   every probability in `[0, 1]`, the read-time trio summing to ≤ 1).
//!   The legacy [`FaultModel::corrupt_string`] draw-per-cell path is kept
//!   for the block-level unit tests.
//! * [`FaultState`] — *persistent, progressive* fault state. Every
//!   corruption decision is a **pure hash** of
//!   `(fault seed, physical string key, cell, program epoch)` through
//!   [`crate::testutil::derive_seed`], never a sequential RNG draw, so
//!
//!   - stuck cells are durable across reprogramming (keyed without the
//!     epoch — rewriting a string lands on the same defective cells),
//!   - retention drift ages monotonically on a logical clock (a cell
//!     drifts once `1 − (1−p)^age` passes its per-cell threshold) and is
//!     healed by reprogramming (the epoch bump redraws thresholds with
//!     zero age),
//!   - read disturb accumulates with the *actual sense count* booked by
//!     the honest iteration accounting, and likewise resets on reprogram,
//!   - the no-fault path consumes **zero** RNG draws, so seeded clean
//!     runs stay bitwise identical to a build without this module.
//!
//! [`ScrubConfig`] parameterizes the online scrubbing / spare-remap pass
//! ([`crate::search::engine::SearchEngine::scrub`]).

use crate::search::api::EngineError;
use crate::testutil::{derive_seed, Rng};
use crate::CELLS_PER_STRING;

/// A fault model applied to programmed cell levels at read time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    /// Probability a cell is stuck at level 0 (erase-state defect).
    pub stuck_low: f64,
    /// Probability a cell is stuck at level 3 (program-state defect).
    pub stuck_high: f64,
    /// Probability a cell drifts one level toward 0 (retention loss) —
    /// under [`FaultState`] this is the per-logical-tick rate, compounded
    /// as `1 − (1−p)^age` since the string was last programmed.
    pub retention_drift: f64,
    /// Per-sense probability a cell is soft-programmed one level *up*
    /// (read disturb), compounded as `1 − (1−p)^senses` over the senses
    /// the string actually absorbed since its last program.
    pub read_disturb: f64,
}

impl FaultModel {
    pub const NONE: FaultModel = FaultModel {
        stuck_low: 0.0,
        stuck_high: 0.0,
        retention_drift: 0.0,
        read_disturb: 0.0,
    };

    /// Mild end-of-life profile.
    pub fn worn() -> FaultModel {
        FaultModel {
            stuck_low: 0.002,
            stuck_high: 0.002,
            retention_drift: 0.02,
            read_disturb: 0.0,
        }
    }

    pub fn is_none(&self) -> bool {
        *self == Self::NONE
    }

    /// Validate the rate parameters: each probability must be a finite
    /// value in `[0, 1]`, and the mutually exclusive read-time draws
    /// (`stuck_low + stuck_high + retention_drift`) must sum to ≤ 1.
    /// `stuck_low = 1.1` used to silently stick *every* cell and negative
    /// rates never fired — both are now typed [`EngineError::InvalidConfig`].
    pub fn validate(&self) -> Result<(), EngineError> {
        for (name, p) in [
            ("stuck_low", self.stuck_low),
            ("stuck_high", self.stuck_high),
            ("retention_drift", self.retention_drift),
            ("read_disturb", self.read_disturb),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(EngineError::InvalidConfig(format!(
                    "fault probability {name} = {p} must be in [0, 1]"
                )));
            }
        }
        let sum = self.stuck_low + self.stuck_high + self.retention_drift;
        if sum > 1.0 {
            return Err(EngineError::InvalidConfig(format!(
                "stuck_low + stuck_high + retention_drift = {sum} exceeds 1"
            )));
        }
        Ok(())
    }

    /// Apply the model to a string's programmed levels (in place), one
    /// RNG draw per cell. Legacy block-level path (program-time only, no
    /// persistence); the engine serves faults through [`FaultState`].
    /// Returns the number of corrupted cells.
    pub fn corrupt_string(&self, cells: &mut [u8; CELLS_PER_STRING], rng: &mut Rng) -> usize {
        if self.is_none() {
            return 0;
        }
        let mut corrupted = 0;
        for cell in cells.iter_mut() {
            let u = rng.next_f64();
            if u < self.stuck_low {
                if *cell != 0 {
                    corrupted += 1;
                }
                *cell = 0;
            } else if u < self.stuck_low + self.stuck_high {
                if *cell != 3 {
                    corrupted += 1;
                }
                *cell = 3;
            } else if u < self.stuck_low + self.stuck_high + self.retention_drift && *cell > 0 {
                *cell -= 1;
                corrupted += 1;
            }
        }
        corrupted
    }
}

/// Domain-separation salts for the independent per-cell hash streams.
const STUCK_SALT: u64 = 0x57;
const DRIFT_SALT: u64 = 0xD12F7;
const DISTURB_SALT: u64 = 0xD157;

/// What a cell is stuck at, if anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StuckAt {
    Free,
    Low,
    High,
}

/// Persistent fault state for one engine: rate model + seed + logical
/// retention clock. Per-string bookkeeping (program epoch, age and sense
/// counters) lives with the slot table in the engine; this type answers
/// "what does physical string `key` read as, given that bookkeeping" as
/// a pure function — replaying a campaign from the same seed is bitwise.
#[derive(Debug, Clone, Copy)]
pub struct FaultState {
    pub model: FaultModel,
    /// Fault stream seed (derive it from the engine seed so one
    /// `EngineConfig::with_seed` value still pins the whole run).
    pub seed: u64,
    /// Logical retention clock, advanced by
    /// [`crate::search::engine::SearchEngine::advance_age`].
    pub age: u64,
}

impl FaultState {
    pub fn new(model: FaultModel, seed: u64) -> FaultState {
        FaultState { model, seed, age: 0 }
    }

    pub fn is_none(&self) -> bool {
        self.model.is_none()
    }

    /// Uniform `[0, 1)` hash of `(salt-domain seed, string key, cell,
    /// extra)` — the per-cell threshold draw.
    fn unit_hash(&self, salt: u64, key: u64, cell: u64, extra: u64) -> f64 {
        let h = derive_seed(derive_seed(derive_seed(self.seed ^ salt, key), cell), extra);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// The durable defect at `(key, cell)`. Keyed **without** the program
    /// epoch: reprogramming the string lands on the same stuck cells —
    /// only remapping to a different physical key escapes them.
    pub fn stuck_at(&self, key: u64, cell: usize) -> StuckAt {
        if self.model.stuck_low == 0.0 && self.model.stuck_high == 0.0 {
            return StuckAt::Free;
        }
        let u = self.unit_hash(STUCK_SALT, key, cell as u64, 0);
        if u < self.model.stuck_low {
            StuckAt::Low
        } else if u < self.model.stuck_low + self.model.stuck_high {
            StuckAt::High
        } else {
            StuckAt::Free
        }
    }

    /// Count of stuck cells on a string (remap-policy input).
    pub fn stuck_cells(&self, key: u64) -> usize {
        (0..CELLS_PER_STRING)
            .filter(|&c| self.stuck_at(key, c) != StuckAt::Free)
            .count()
    }

    /// Read `intended` through the fault overlay: retention drift (one
    /// level down after `age_since_program` logical ticks beat the cell's
    /// threshold), then read disturb (one level up after `senses` reads
    /// beat it), then stuck-at defects override everything. Pure — no RNG
    /// stream is consumed. Returns `(cells, corrupted_count)`.
    pub fn read_string(
        &self,
        key: u64,
        epoch: u32,
        age_since_program: u64,
        senses: u64,
        intended: &[u8; CELLS_PER_STRING],
    ) -> ([u8; CELLS_PER_STRING], usize) {
        let mut out = *intended;
        if self.is_none() {
            return (out, 0);
        }
        let drift_p = cumulative(self.model.retention_drift, age_since_program);
        let disturb_p = cumulative(self.model.read_disturb, senses);
        let mut corrupted = 0usize;
        for (c, cell) in out.iter_mut().enumerate() {
            let want = *cell;
            if drift_p > 0.0
                && *cell > 0
                && self.unit_hash(DRIFT_SALT, key, c as u64, epoch as u64) < drift_p
            {
                *cell -= 1;
            }
            if disturb_p > 0.0
                && *cell < 3
                && self.unit_hash(DISTURB_SALT, key, c as u64, epoch as u64) < disturb_p
            {
                *cell += 1;
            }
            match self.stuck_at(key, c) {
                StuckAt::Low => *cell = 0,
                StuckAt::High => *cell = 3,
                StuckAt::Free => {}
            }
            if *cell != want {
                corrupted += 1;
            }
        }
        (out, corrupted)
    }
}

/// `1 − (1−p)^n`: probability at least one of `n` independent trials at
/// rate `p` fired — monotone in `n`, so aging never un-drifts a cell.
fn cumulative(p: f64, n: u64) -> f64 {
    if p <= 0.0 || n == 0 {
        0.0
    } else if p >= 1.0 {
        1.0
    } else {
        1.0 - (1.0 - p).powf(n as f64)
    }
}

/// Online-scrubbing policy knobs (`[scrub]` TOML section; DESIGN.md
/// §Reliability). Scrubbing is opt-in: a default-constructed engine
/// reserves no spares and programs no canaries, keeping the clean path
/// bitwise identical to builds without the reliability layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScrubConfig {
    /// Known-pattern canary strings per shard, re-sensed by every scrub
    /// pass to estimate margin loss.
    pub canaries: usize,
    /// Spare slots per shard for remapping strings with persistent stuck
    /// faults.
    pub spares: usize,
    /// Canary cell-match fraction below which the shard is `Degraded`.
    pub margin_threshold: f64,
    /// Remap a slot to a spare once this many of its cells are stuck
    /// (reprogramming cannot heal stuck cells; light damage is cheaper
    /// to tolerate than to burn a spare on).
    pub remap_stuck_cells: usize,
}

impl Default for ScrubConfig {
    fn default() -> Self {
        ScrubConfig {
            canaries: 4,
            spares: 2,
            margin_threshold: 0.9,
            remap_stuck_cells: 1,
        }
    }
}

impl ScrubConfig {
    pub fn validate(&self) -> Result<(), EngineError> {
        if !self.margin_threshold.is_finite() || !(0.0..=1.0).contains(&self.margin_threshold) {
            return Err(EngineError::InvalidConfig(format!(
                "scrub margin_threshold = {} must be in [0, 1]",
                self.margin_threshold
            )));
        }
        if self.canaries == 0 {
            return Err(EngineError::InvalidConfig(
                "scrub needs at least one canary string per shard".to_string(),
            ));
        }
        if self.remap_stuck_cells == 0 {
            return Err(EngineError::InvalidConfig(
                "remap_stuck_cells must be >= 1 (0 would remap healthy strings)".to_string(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let mut rng = Rng::new(1);
        let mut cells = [2u8; CELLS_PER_STRING];
        assert_eq!(FaultModel::NONE.corrupt_string(&mut cells, &mut rng), 0);
        assert_eq!(cells, [2u8; CELLS_PER_STRING]);
    }

    #[test]
    fn stuck_low_zeroes_cells() {
        let model = FaultModel { stuck_low: 1.0, ..FaultModel::NONE };
        let mut rng = Rng::new(2);
        let mut cells = [3u8; CELLS_PER_STRING];
        let n = model.corrupt_string(&mut cells, &mut rng);
        assert_eq!(n, CELLS_PER_STRING);
        assert_eq!(cells, [0u8; CELLS_PER_STRING]);
    }

    #[test]
    fn retention_drifts_one_level_down() {
        let model = FaultModel { retention_drift: 1.0, ..FaultModel::NONE };
        let mut rng = Rng::new(3);
        let mut cells = [2u8; CELLS_PER_STRING];
        model.corrupt_string(&mut cells, &mut rng);
        assert_eq!(cells, [1u8; CELLS_PER_STRING]);
        // level-0 cells cannot drift below 0
        let mut zeros = [0u8; CELLS_PER_STRING];
        assert_eq!(model.corrupt_string(&mut zeros, &mut rng), 0);
        assert_eq!(zeros, [0u8; CELLS_PER_STRING]);
    }

    #[test]
    fn corruption_rate_tracks_probability() {
        let model = FaultModel { stuck_low: 0.05, ..FaultModel::NONE };
        let mut rng = Rng::new(4);
        let mut total = 0usize;
        let trials = 2000;
        for _ in 0..trials {
            let mut cells = [1u8; CELLS_PER_STRING];
            total += model.corrupt_string(&mut cells, &mut rng);
        }
        let rate = total as f64 / (trials * CELLS_PER_STRING) as f64;
        assert!((rate - 0.05).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn validation_rejects_out_of_range_rates() {
        assert!(FaultModel::NONE.validate().is_ok());
        assert!(FaultModel::worn().validate().is_ok());
        for bad in [
            FaultModel { stuck_low: 1.1, ..FaultModel::NONE },
            FaultModel { stuck_high: -0.2, ..FaultModel::NONE },
            FaultModel { retention_drift: f64::NAN, ..FaultModel::NONE },
            FaultModel { read_disturb: f64::INFINITY, ..FaultModel::NONE },
            FaultModel {
                stuck_low: 0.5,
                stuck_high: 0.4,
                retention_drift: 0.2,
                read_disturb: 0.0,
            },
        ] {
            assert!(
                matches!(bad.validate(), Err(EngineError::InvalidConfig(_))),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn stuck_cells_survive_reprogramming() {
        let state = FaultState::new(
            FaultModel { stuck_low: 0.2, stuck_high: 0.2, ..FaultModel::NONE },
            0xFA017,
        );
        let intended = [2u8; CELLS_PER_STRING];
        let (epoch0, n0) = state.read_string(9, 0, 0, 0, &intended);
        assert!(n0 > 0, "40% stuck rate must hit a 24-cell string");
        // epoch bump (reprogram) lands on the same defects
        let (epoch5, n5) = state.read_string(9, 5, 0, 0, &intended);
        assert_eq!(epoch0, epoch5);
        assert_eq!(n0, n5);
        // a different physical key escapes them (almost surely differs)
        let (other, _) = state.read_string(10, 0, 0, 0, &intended);
        assert_ne!(epoch0, other);
    }

    #[test]
    fn retention_is_monotone_in_age_and_healed_by_epoch_bump() {
        let state = FaultState::new(
            FaultModel { retention_drift: 0.05, ..FaultModel::NONE },
            0xA6E,
        );
        let intended = [3u8; CELLS_PER_STRING];
        let mut drifted_prev = 0usize;
        for age in [0u64, 1, 5, 20, 80] {
            let (_, drifted) = state.read_string(3, 0, age, 0, &intended);
            assert!(drifted >= drifted_prev, "aging must never heal drift");
            drifted_prev = drifted;
        }
        assert!(drifted_prev > 0, "80 ticks at 5%/tick must drift something");
        // reprogramming at the same age resets the since-program clock
        let (healed, n) = state.read_string(3, 1, 0, 0, &intended);
        assert_eq!(n, 0);
        assert_eq!(healed, intended);
    }

    #[test]
    fn read_disturb_accumulates_with_senses_and_shifts_up() {
        let state = FaultState::new(
            FaultModel { read_disturb: 0.001, ..FaultModel::NONE },
            0xD15,
        );
        let intended = [1u8; CELLS_PER_STRING];
        let (fresh, n_fresh) = state.read_string(7, 0, 0, 0, &intended);
        assert_eq!((fresh, n_fresh), (intended, 0));
        let (worn, n_worn) = state.read_string(7, 0, 0, 5000, &intended);
        assert!(n_worn > 0, "5000 senses at 1e-3/sense must disturb");
        for (w, i) in worn.iter().zip(&intended) {
            assert!(w >= i, "disturb shifts levels up, never down");
        }
        // reset by reprogram (sense counter restarts under a new epoch)
        let (reset, n_reset) = state.read_string(7, 1, 0, 0, &intended);
        assert_eq!((reset, n_reset), (intended, 0));
    }

    #[test]
    fn overlay_is_a_pure_function() {
        let state = FaultState::new(FaultModel::worn(), 0xB17);
        let intended = [2u8; CELLS_PER_STRING];
        let a = state.read_string(42, 3, 17, 900, &intended);
        let b = state.read_string(42, 3, 17, 900, &intended);
        assert_eq!(a, b, "same inputs, same corruption — replay is bitwise");
    }

    #[test]
    fn scrub_config_validation() {
        assert!(ScrubConfig::default().validate().is_ok());
        let bad_margin = ScrubConfig { margin_threshold: 1.5, ..Default::default() };
        assert!(matches!(bad_margin.validate(), Err(EngineError::InvalidConfig(_))));
        let no_canary = ScrubConfig { canaries: 0, ..Default::default() };
        assert!(matches!(no_canary.validate(), Err(EngineError::InvalidConfig(_))));
        let zero_remap = ScrubConfig { remap_stuck_cells: 0, ..Default::default() };
        assert!(matches!(zero_remap.validate(), Err(EngineError::InvalidConfig(_))));
    }
}
