//! Parametric search-energy model (Fig. 9's x-axis).
//!
//! The paper estimates search energy from the measurements of [14]; those
//! absolute numbers are not public, so we use a parametric model whose
//! constants are shared by every encoding — the Pareto *ordering* of
//! Fig. 9 is invariant to the absolute scale (DESIGN.md §2):
//!
//! ```text
//! E_search = Σ_iterations ( sensed_strings × 24 × E_cell
//!                         + sensed_strings × T × E_sa )
//! ```
//!
//! where `T` is the SA ladder depth. Under a full SVSS or AVSS scan a
//! support vector's `groups × word_length` strings are each sensed
//! exactly once per search, so at equal code word length the two modes
//! cost the same energy — AVSS wins *iterations* (throughput), not
//! energy, exactly as in the paper.
//!
//! **Honest accounting** (DESIGN.md §Cascade): `sensed_strings` counts
//! only strings *actually* sensed. `slots × groups × word_length` per
//! search is the full-scan **upper bound**; a progressive-precision
//! cascade ([`crate::search::cascade`]) senses a column prefix of every
//! slot and then only its shortlist, and books each stage's true string
//! count (at that stage's ladder depth) through [`EnergyAccount::add_sense`].

use crate::CELLS_PER_STRING;

/// Energy constants, in picojoules per event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// Per cell-evaluation (word-line drive of one unit cell).
    pub e_cell_pj: f64,
    /// Per SA threshold comparison on one string.
    pub e_sa_pj: f64,
    /// Per cell *programmed* (ISPP pulse train) — scrub reprogramming and
    /// spare remapping book program/erase cycles through this.
    pub e_program_pj: f64,
    /// Per string erased (block-erase cost amortized per string).
    pub e_erase_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // [14]-plausible magnitudes: ~10 fJ/cell search event, ~0.5 pJ per
        // SA comparison; programming is orders costlier than sensing
        // (ISPP pulse trains vs a single drive). Only ratios matter for
        // the reproduced figures.
        EnergyModel { e_cell_pj: 0.01, e_sa_pj: 0.5, e_program_pj: 10.0, e_erase_pj: 50.0 }
    }
}

impl EnergyModel {
    /// Energy of sensing `strings` strings once through a `ladder_len`
    /// threshold ladder.
    pub fn sense_energy_pj(&self, strings: u64, ladder_len: usize) -> f64 {
        strings as f64
            * (CELLS_PER_STRING as f64 * self.e_cell_pj + ladder_len as f64 * self.e_sa_pj)
    }

    /// Energy of one erase + reprogram cycle over `strings` strings.
    pub fn program_energy_pj(&self, strings: u64) -> f64 {
        strings as f64 * (CELLS_PER_STRING as f64 * self.e_program_pj + self.e_erase_pj)
    }
}

/// Running energy account for a workload.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyAccount {
    pub total_pj: f64,
    pub sensed_strings: u64,
    pub searches: u64,
    /// Strings rewritten by scrub passes (program/erase cycles).
    pub programmed_strings: u64,
}

impl EnergyAccount {
    pub fn add_sense(&mut self, model: &EnergyModel, strings: u64, ladder_len: usize) {
        self.total_pj += model.sense_energy_pj(strings, ladder_len);
        self.sensed_strings += strings;
    }

    /// Book an erase + reprogram cycle over `strings` strings (the scrub
    /// path's P/E cost — folded into the same per-search ledger so a
    /// scrubbed campaign's energy numbers stay honest).
    pub fn add_program(&mut self, model: &EnergyModel, strings: u64) {
        self.total_pj += model.program_energy_pj(strings);
        self.programmed_strings += strings;
    }

    pub fn finish_search(&mut self) {
        self.searches += 1;
    }

    /// Average energy per search, in nanojoules.
    pub fn nj_per_search(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.total_pj / 1000.0 / self.searches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn sense_energy_formula() {
        let m = EnergyModel { e_cell_pj: 0.01, e_sa_pj: 0.5, ..Default::default() };
        // 10 strings: 10 * (24*0.01 + 16*0.5) = 10 * 8.24 = 82.4 pJ
        assert_close(m.sense_energy_pj(10, 16), 82.4, 1e-12);
    }

    #[test]
    fn program_energy_books_pe_cycles() {
        let m = EnergyModel::default();
        let mut acc = EnergyAccount::default();
        acc.add_program(&m, 16);
        acc.finish_search();
        assert_eq!(acc.programmed_strings, 16);
        // 16 * (24*10 + 50) = 4640 pJ
        assert_close(acc.total_pj, 4640.0, 1e-12);
        assert!(
            m.program_energy_pj(1) > m.sense_energy_pj(1, 32),
            "a P/E cycle must dominate even a deep sense"
        );
    }

    #[test]
    fn account_accumulates() {
        let m = EnergyModel::default();
        let mut acc = EnergyAccount::default();
        acc.add_sense(&m, 100, 16);
        acc.finish_search();
        acc.add_sense(&m, 100, 16);
        acc.finish_search();
        assert_eq!(acc.searches, 2);
        assert_eq!(acc.sensed_strings, 200);
        assert_close(
            acc.nj_per_search(),
            m.sense_energy_pj(100, 16) / 1000.0,
            1e-12,
        );
    }

    #[test]
    fn empty_account_is_zero() {
        assert_eq!(EnergyAccount::default().nj_per_search(), 0.0);
    }

    #[test]
    fn energy_scales_with_word_length() {
        // Fig. 9's x-axis: longer code words → more strings → more energy.
        let m = EnergyModel::default();
        let short = m.sense_energy_pj(2 * 4, 16); // groups=2, CL=4
        let long = m.sense_energy_pj(2 * 16, 16); // groups=2, CL=16
        assert!(long > short * 3.9);
    }
}
