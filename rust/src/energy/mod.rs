//! Parametric search-energy model (Fig. 9's x-axis).
//!
//! The paper estimates search energy from the measurements of [14]; those
//! absolute numbers are not public, so we use a parametric model whose
//! constants are shared by every encoding — the Pareto *ordering* of
//! Fig. 9 is invariant to the absolute scale (DESIGN.md §2):
//!
//! ```text
//! E_search = Σ_iterations ( sensed_strings × 24 × E_cell
//!                         + sensed_strings × T × E_sa )
//! ```
//!
//! where `T` is the SA ladder depth. Under a full SVSS or AVSS scan a
//! support vector's `groups × word_length` strings are each sensed
//! exactly once per search, so at equal code word length the two modes
//! cost the same energy — AVSS wins *iterations* (throughput), not
//! energy, exactly as in the paper.
//!
//! **Honest accounting** (DESIGN.md §Cascade): `sensed_strings` counts
//! only strings *actually* sensed. `slots × groups × word_length` per
//! search is the full-scan **upper bound**; a progressive-precision
//! cascade ([`crate::search::cascade`]) senses a column prefix of every
//! slot and then only its shortlist, and books each stage's true string
//! count (at that stage's ladder depth) through [`EnergyAccount::add_sense`].

use crate::CELLS_PER_STRING;

/// Energy constants, in picojoules per event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// Per cell-evaluation (word-line drive of one unit cell).
    pub e_cell_pj: f64,
    /// Per SA threshold comparison on one string.
    pub e_sa_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // [14]-plausible magnitudes: ~10 fJ/cell search event, ~0.5 pJ per
        // SA comparison. Only ratios matter for the reproduced figures.
        EnergyModel { e_cell_pj: 0.01, e_sa_pj: 0.5 }
    }
}

impl EnergyModel {
    /// Energy of sensing `strings` strings once through a `ladder_len`
    /// threshold ladder.
    pub fn sense_energy_pj(&self, strings: u64, ladder_len: usize) -> f64 {
        strings as f64
            * (CELLS_PER_STRING as f64 * self.e_cell_pj + ladder_len as f64 * self.e_sa_pj)
    }
}

/// Running energy account for a workload.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyAccount {
    pub total_pj: f64,
    pub sensed_strings: u64,
    pub searches: u64,
}

impl EnergyAccount {
    pub fn add_sense(&mut self, model: &EnergyModel, strings: u64, ladder_len: usize) {
        self.total_pj += model.sense_energy_pj(strings, ladder_len);
        self.sensed_strings += strings;
    }

    pub fn finish_search(&mut self) {
        self.searches += 1;
    }

    /// Average energy per search, in nanojoules.
    pub fn nj_per_search(&self) -> f64 {
        if self.searches == 0 {
            0.0
        } else {
            self.total_pj / 1000.0 / self.searches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn sense_energy_formula() {
        let m = EnergyModel { e_cell_pj: 0.01, e_sa_pj: 0.5 };
        // 10 strings: 10 * (24*0.01 + 16*0.5) = 10 * 8.24 = 82.4 pJ
        assert_close(m.sense_energy_pj(10, 16), 82.4, 1e-12);
    }

    #[test]
    fn account_accumulates() {
        let m = EnergyModel::default();
        let mut acc = EnergyAccount::default();
        acc.add_sense(&m, 100, 16);
        acc.finish_search();
        acc.add_sense(&m, 100, 16);
        acc.finish_search();
        assert_eq!(acc.searches, 2);
        assert_eq!(acc.sensed_strings, 200);
        assert_close(
            acc.nj_per_search(),
            m.sense_energy_pj(100, 16) / 1000.0,
            1e-12,
        );
    }

    #[test]
    fn empty_account_is_zero() {
        assert_eq!(EnergyAccount::default().nj_per_search(), 0.0);
    }

    #[test]
    fn energy_scales_with_word_length() {
        // Fig. 9's x-axis: longer code words → more strings → more energy.
        let m = EnergyModel::default();
        let short = m.sense_energy_pj(2 * 4, 16); // groups=2, CL=4
        let long = m.sense_energy_pj(2 * 16, 16); // groups=2, CL=16
        assert!(long > short * 3.9);
    }
}
