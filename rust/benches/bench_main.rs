//! Benchmark harness (criterion is not vendored in the offline image, so
//! this is a hand-rolled `harness = false` bench binary).
//!
//! Two kinds of targets, selectable by substring filter
//! (`cargo bench -- fig9`):
//!
//! * **paper targets** — regenerate every table/figure of the paper's
//!   evaluation (table1, fig2, fig3, fig5, fig6, fig7, fig9_omniglot,
//!   fig9_cub, table2, headline); these print the same rows/series the
//!   paper reports and are recorded in DESIGN.md §Perf;
//! * **perf targets** (`perf_`) — microbenchmarks of the L3 hot path
//!   (fused sense kernel, block search, engine end-to-end,
//!   batched/sharded search, top-k selection, coordinator overhead) with
//!   throughput numbers for DESIGN.md §Perf.
//!
//! The tracked perf targets (`perf_kernel`, `perf_engine`,
//! `perf_batch_shards`, `perf_topk`, `perf_cascade`, `perf_routing`)
//! additionally write their measurements into `BENCH_engine.json` at the
//! repository root under the build's `BENCH_RUN_ID` (an **append-only**
//! per-PR record: the deep merge only touches the current run's slot,
//! so prior PRs' entries — and other targets' sections from partial
//! runs — always survive; DESIGN.md §Perf). `perf_kernel` asserts its
//! perf floors every run: ≥2× vs the naive reference, ≥1.5× for SIMD vs
//! scalar fused when built with `--features simd`, and no worse than
//! 0.6× the best previously recorded run. `perf_cascade`
//! doubles as the cascade acceptance smoke: ≥2× sensed-string reduction
//! at ≤0.5% synth accuracy drop is asserted on every run. `perf_routing`
//! does the same for the shard-routing tier: ≥4× sensed-shard reduction
//! at ≤1% accuracy drop on the clustered smoke episode.

use mcamvss::coordinator::{CoordinatorConfig, Payload, Server};
use mcamvss::device::block::McamBlock;
use mcamvss::device::sense::SenseLadder;
use mcamvss::device::variation::VariationModel;
use mcamvss::device::McamParams;
use mcamvss::encoding::Encoding;
use mcamvss::experiments::{self, EpisodeSettings};
use mcamvss::fsl::store::ArtifactStore;
use mcamvss::search::engine::{EngineConfig, SearchEngine};
use mcamvss::search::{SearchMode, SearchRequest};
use mcamvss::testutil::Rng;
use mcamvss::util::json::{keyed_by_run, Json, ObjBuilder, BENCH_RUN_ID};
use mcamvss::CELLS_PER_STRING;
use std::path::Path;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // cargo bench passes --bench; ignore flags, keep substring filters
    let filters: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with('-'))
        .map(|s| s.as_str())
        .collect();
    let want = |name: &str| filters.is_empty() || filters.iter().any(|f| name.contains(f));

    let store = ArtifactStore::open_default().ok();
    if store.is_none() {
        eprintln!("NOTE: artifacts not built; artifact-driven benches will be skipped");
    }

    // ---------------- paper targets ----------------
    if want("table1") {
        section("table1");
        println!("{}", experiments::table1::render());
    }
    if want("headline") {
        section("headline");
        println!("{}", experiments::headline::render_iteration_claims());
    }
    if want("fig2") {
        section("fig2");
        let t0 = Instant::now();
        println!("{}", experiments::fig2::render());
        println!("[fig2 wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
    if want("fig3") {
        section("fig3 (B4E)");
        println!("{}", experiments::fig3_5::render_panel_b(Encoding::B4e));
        if let Some(store) = &store {
            let rows = experiments::fig3_5::panel_a(
                store,
                "omniglot",
                "std",
                Encoding::B4e,
                &[1, 2, 4, 8],
                20_000,
                0x3A,
            )
            .unwrap();
            println!("{}", experiments::fig3_5::render_panel_a(&rows));
        }
    }
    if want("fig5") {
        section("fig5 (MTMC)");
        println!("{}", experiments::fig3_5::render_panel_b(Encoding::Mtmc));
        if let Some(store) = &store {
            let rows = experiments::fig3_5::panel_a(
                store,
                "omniglot",
                "std",
                Encoding::Mtmc,
                &[1, 2, 4, 8],
                20_000,
                0x5A,
            )
            .unwrap();
            println!("{}", experiments::fig3_5::render_panel_a(&rows));
        }
    }
    if want("fig6") {
        if let Some(store) = &store {
            section("fig6");
            for ds in ["omniglot", "cub"] {
                let stats = experiments::fig6::run(store, ds, "std", 8, 3000, 6).unwrap();
                println!("dataset {ds}:\n{}", experiments::fig6::render(&stats));
            }
        }
    }
    if want("fig7") {
        if let Some(store) = &store {
            section("fig7");
            for ds in ["omniglot", "cub"] {
                let t0 = Instant::now();
                let bars =
                    experiments::fig7::run(store, ds, 8, EpisodeSettings::for_dataset(ds))
                        .unwrap();
                println!("{}", experiments::fig7::render(ds, &bars));
                println!("[fig7 {ds} wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
        }
    }
    if want("fig9_omniglot") {
        if let Some(store) = &store {
            section("fig9 omniglot");
            let t0 = Instant::now();
            let points =
                experiments::fig9::run(store, "omniglot", EpisodeSettings::omniglot()).unwrap();
            println!("{}", experiments::fig9::render("omniglot", &points));
            println!("[fig9 omniglot wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
        }
    }
    if want("fig9_cub") {
        if let Some(store) = &store {
            section("fig9 cub");
            let t0 = Instant::now();
            let points = experiments::fig9::run(store, "cub", EpisodeSettings::cub()).unwrap();
            println!("{}", experiments::fig9::render("cub", &points));
            println!("[fig9 cub wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
        }
    }
    if want("table2") {
        if let Some(store) = &store {
            section("table2");
            for ds in ["omniglot", "cub"] {
                let t0 = Instant::now();
                let cells =
                    experiments::table2::run(store, ds, EpisodeSettings::for_dataset(ds))
                        .unwrap();
                println!("{}", experiments::table2::render(&cells));
                println!("[table2 {ds} wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
            }
        }
    }

    // perf_cascade renders the same sweep; skip the figure section when
    // both would run (an unfiltered `cargo bench`) so the sweep executes
    // once.
    if want("fig_cascade") && !want("perf_cascade") {
        section("fig_cascade");
        let t0 = Instant::now();
        let sweep = experiments::fig_cascade::run(0xCA5CADE).unwrap();
        println!("{}", experiments::fig_cascade::render(&sweep));
        println!("[fig_cascade wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
    }

    if want("fig_faults") {
        section("fig_faults");
        let t0 = Instant::now();
        let sweep = experiments::fig_faults::run(0xFA0175).unwrap();
        println!("{}", experiments::fig_faults::render(&sweep));
        println!("[fig_faults wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
    }

    // perf_routing renders the same sweep; skip the figure section when
    // both would run so it executes once.
    if want("fig_routing") && !want("perf_routing") {
        section("fig_routing");
        let t0 = Instant::now();
        let sweep = experiments::fig_routing::run(0xC0A25E).unwrap();
        println!("{}", experiments::fig_routing::render(&sweep));
        println!("[fig_routing wall: {:.1}s]\n", t0.elapsed().as_secs_f64());
    }

    if want("ablation") {
        if let Some(store) = &store {
            section("ablations");
            let settings = EpisodeSettings {
                n_way: 100,
                k_shot: 5,
                n_query: 2,
                episodes: 2,
                seed: 0xAB,
            };
            let rows = experiments::ablation::ladder_depth(store, "omniglot", settings).unwrap();
            println!("{}", experiments::ablation::render("SA ladder depth (omniglot)", &rows));
            let rows =
                experiments::ablation::variation_severity(store, "omniglot", settings).unwrap();
            println!(
                "{}",
                experiments::ablation::render("variation severity, MTMC vs B4E (omniglot)", &rows)
            );
            let rows =
                experiments::ablation::fault_injection(store, "omniglot", settings).unwrap();
            println!("{}", experiments::ablation::render("fault injection (omniglot)", &rows));
        }
    }

    // ---------------- perf targets ----------------
    let mut report: Vec<(String, Json)> = Vec::new();
    if want("perf_kernel") {
        section("perf_kernel");
        perf_kernel(&mut report);
    }
    if want("perf_block_search") {
        section("perf_block_search");
        perf_block_search();
    }
    if want("perf_engine") {
        section("perf_engine");
        perf_engine(&mut report);
    }
    if want("perf_batch_shards") {
        section("perf_batch_shards");
        perf_batch_shards(&mut report);
    }
    if want("perf_topk") {
        section("perf_topk");
        perf_topk(&mut report);
    }
    if want("perf_cascade") {
        section("perf_cascade");
        perf_cascade(&mut report);
    }
    if want("perf_routing") {
        section("perf_routing");
        perf_routing(&mut report);
    }
    if want("perf_coordinator") {
        section("perf_coordinator");
        perf_coordinator();
    }
    if want("perf_sense") {
        section("perf_sense");
        perf_sense();
    }
    write_report(report);
}

/// Merge the measured perf entries into `BENCH_engine.json` at the repo
/// root via [`mcamvss::util::json::merge_report`]. Each section is
/// recorded under the current [`BENCH_RUN_ID`] (`{target: {run_id:
/// {...}}}`), and `merge_report`'s deep-merge only touches that id's
/// slot — the committed record is append-only across PRs (DESIGN.md
/// §Perf). The `bench-client` CLI subcommand merges into the same
/// report the same way.
fn write_report(entries: Vec<(String, Json)>) {
    if entries.is_empty() {
        return;
    }
    let path = report_path();
    let keyed = entries.into_iter().map(|(k, v)| (k, keyed_by_run(v))).collect();
    match mcamvss::util::json::merge_report(&path, keyed) {
        Ok(()) => println!("[bench report → {} under run id {BENCH_RUN_ID}]", path.display()),
        Err(e) => eprintln!("WARNING: could not write {}: {e}", path.display()),
    }
}

/// `BENCH_engine.json` at the repository root.
fn report_path() -> std::path::PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate has a parent dir");
    root.join("BENCH_engine.json")
}

/// Best `kernel_mcells_per_s` recorded in `BENCH_engine.json` by any
/// *previous* run (any `perf_kernel` entry whose run id differs from
/// [`BENCH_RUN_ID`]). `None` when there is no comparable prior entry.
fn recorded_prior_kernel_throughput() -> Option<f64> {
    let text = std::fs::read_to_string(report_path()).ok()?;
    let parsed = Json::parse(&text).ok()?;
    let Json::Obj(runs) = parsed.get("perf_kernel")? else {
        return None;
    };
    runs.iter()
        .filter(|(run, _)| run.as_str() != BENCH_RUN_ID)
        .filter_map(|(_, entry)| entry.get("kernel_mcells_per_s")?.as_f64())
        .filter(|&t| t > 0.0)
        .fold(None, |best: Option<f64>, t| Some(best.map_or(t, |b| b.max(t))))
}

fn section(name: &str) {
    println!("==================== {name} ====================");
}

/// Acceptance microbench (ISSUE 2, extended in ISSUE 10): every
/// sense→vote→accumulate kernel variant on a fully occupied ideal
/// block — the per-string naive walk, a bench-local replica of the
/// pre-tiling **string-major** storage (the honest PR-1 baseline), the
/// scalar fused kernel, the integer-vote-accumulation kernel, the
/// dispatcher (whatever [`McamBlock::active_kernel`] resolves to), and
/// the SIMD kernel when built with `--features simd`. All paths must
/// produce bit-identical scores. Asserted perf floors: the dispatched
/// kernel ≥2× the naive reference; under `--features simd` the SIMD
/// kernel ≥1.5× the scalar fused kernel; and the dispatched throughput
/// must not regress below 0.6× the best entry any previous run
/// recorded in `BENCH_engine.json` (the 0.6 bar absorbs machine-to-
/// machine variance while catching real regressions — DESIGN.md §Perf).
fn perf_kernel(report: &mut Vec<(String, Json)>) {
    let n = mcamvss::STRINGS_PER_BLOCK;
    let params = McamParams::default();
    let mut rng = Rng::new(11);
    let mut block = McamBlock::new(n, params, VariationModel::IDEAL, 1);
    // replica of the legacy string-major storage, built from the same cells
    let mut legacy_levels: Vec<u8> = Vec::with_capacity(n * CELLS_PER_STRING);
    let mut cells = [0u8; CELLS_PER_STRING];
    for _ in 0..n {
        for c in cells.iter_mut() {
            *c = rng.below(4) as u8;
        }
        legacy_levels.extend_from_slice(&cells);
        block.program_string(&cells);
    }
    let legacy_var = vec![1.0f32; n * CELLS_PER_STRING];
    let mut wordline = [0u8; CELLS_PER_STRING];
    for c in wordline.iter_mut() {
        *c = rng.below(4) as u8;
    }
    let ladder = SenseLadder::new(&params, 16);
    let lut = params.resistance_lut();

    // The PR-1 sense loop verbatim: string-major walk, double-indexed
    // LUT, currents-Vec round-trip, current-domain ladder votes.
    let mut currents: Vec<f64> = Vec::with_capacity(n);
    let mut legacy_pass = |scores: &mut [f64]| {
        currents.clear();
        for idx in 0..n {
            let base = idx * CELLS_PER_STRING;
            let mut series = 0f32;
            for l in 0..CELLS_PER_STRING {
                let q = wordline[l] as usize;
                series += lut[q][legacy_levels[base + l] as usize] * legacy_var[base + l];
            }
            currents.push(params.v_bl / series as f64);
        }
        for (score, &current) in scores.iter_mut().zip(&currents) {
            *score += ladder.votes(current) as f64;
        }
    };

    let reps = 10;
    let mut legacy_scores = vec![0f64; n];
    legacy_pass(&mut legacy_scores); // warmup
    let t0 = Instant::now();
    for _ in 0..reps {
        legacy_pass(&mut legacy_scores);
    }
    let legacy_dt = t0.elapsed().as_secs_f64() / reps as f64;

    let mut naive_scores = vec![0f64; n];
    block.sense_votes_range_naive(&wordline, 0, n, &ladder, 1.0, &mut naive_scores);
    let t0 = Instant::now();
    for _ in 0..reps {
        block.sense_votes_range_naive(&wordline, 0, n, &ladder, 1.0, &mut naive_scores);
    }
    let naive_dt = t0.elapsed().as_secs_f64() / reps as f64;

    let mut scalar_scores = vec![0f64; n];
    block.sense_votes_range_scalar(&wordline, 0, n, &ladder, 1.0, &mut scalar_scores);
    let t0 = Instant::now();
    for _ in 0..reps {
        block.sense_votes_range_scalar(&wordline, 0, n, &ladder, 1.0, &mut scalar_scores);
    }
    let scalar_dt = t0.elapsed().as_secs_f64() / reps as f64;

    let mut int_scores = vec![0f64; n];
    block.sense_votes_range_int(&wordline, 0, n, &ladder, 1.0, &mut int_scores);
    let t0 = Instant::now();
    for _ in 0..reps {
        block.sense_votes_range_int(&wordline, 0, n, &ladder, 1.0, &mut int_scores);
    }
    let int_dt = t0.elapsed().as_secs_f64() / reps as f64;

    #[cfg(feature = "simd")]
    let simd_dt = {
        let mut simd_scores = vec![0f64; n];
        block.sense_votes_range_simd(&wordline, 0, n, &ladder, 1.0, &mut simd_scores);
        let t0 = Instant::now();
        for _ in 0..reps {
            block.sense_votes_range_simd(&wordline, 0, n, &ladder, 1.0, &mut simd_scores);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        assert_eq!(simd_scores, naive_scores, "simd kernel != scalar reference");
        dt
    };

    // the dispatched kernel — whatever variant this build selected
    let kernel = McamBlock::active_kernel();
    let mut fused_scores = vec![0f64; n];
    block.sense_votes_range(&wordline, 0, n, &ladder, 1.0, &mut fused_scores);
    let t0 = Instant::now();
    for _ in 0..reps {
        block.sense_votes_range(&wordline, 0, n, &ladder, 1.0, &mut fused_scores);
    }
    let fused_dt = t0.elapsed().as_secs_f64() / reps as f64;

    // Every path accumulated reps + 1 identical passes: bit-identity is
    // checked end to end on the full block, every run, across every
    // kernel variant this build can express.
    assert_eq!(fused_scores, naive_scores, "dispatched kernel != scalar reference");
    assert_eq!(fused_scores, legacy_scores, "dispatched kernel != string-major replica");
    assert_eq!(scalar_scores, naive_scores, "scalar fused kernel != scalar reference");
    assert_eq!(int_scores, naive_scores, "integer-accum kernel != scalar reference");

    let cell_evals = (n * CELLS_PER_STRING) as f64;
    let speedup_naive = naive_dt / fused_dt;
    let speedup_legacy = legacy_dt / fused_dt;
    let kernel_mcells = cell_evals / fused_dt / 1e6;
    println!(
        "kernel: {n} strings x {CELLS_PER_STRING} cells, ladder 16, {reps} reps \
         (active variant: {})",
        kernel.name()
    );
    println!(
        "  naive reference:     {:.2} ms/pass ({:.0} M cells/s)",
        naive_dt * 1e3,
        cell_evals / naive_dt / 1e6
    );
    println!(
        "  string-major (PR 1): {:.2} ms/pass ({:.0} M cells/s)",
        legacy_dt * 1e3,
        cell_evals / legacy_dt / 1e6
    );
    println!(
        "  scalar fused:        {:.2} ms/pass ({:.0} M cells/s)",
        scalar_dt * 1e3,
        cell_evals / scalar_dt / 1e6
    );
    println!(
        "  integer-accum:       {:.2} ms/pass ({:.0} M cells/s)",
        int_dt * 1e3,
        cell_evals / int_dt / 1e6
    );
    #[cfg(feature = "simd")]
    println!(
        "  simd:                {:.2} ms/pass ({:.0} M cells/s, {:.2}x vs scalar fused)",
        simd_dt * 1e3,
        cell_evals / simd_dt / 1e6,
        scalar_dt / simd_dt
    );
    println!(
        "  dispatched [{}]:     {:.2} ms/pass ({kernel_mcells:.0} M cells/s)",
        kernel.name(),
        fused_dt * 1e3
    );
    println!(
        "  SPEEDUP: {speedup_naive:.2}x vs naive reference (target >= 2x), \
         {speedup_legacy:.2}x vs PR-1 string-major layout\n"
    );
    assert!(
        speedup_naive >= 2.0,
        "dispatched kernel fell below the 2x floor vs the naive reference \
         ({speedup_naive:.2}x)"
    );
    #[cfg(feature = "simd")]
    assert!(
        scalar_dt / simd_dt >= 1.5,
        "simd kernel below the 1.5x floor vs scalar fused ({:.2}x)",
        scalar_dt / simd_dt
    );
    if let Some(prior) = recorded_prior_kernel_throughput() {
        let floor = 0.6 * prior;
        println!(
            "  regression check: {kernel_mcells:.0} M cells/s vs recorded best \
             {prior:.0} (floor {floor:.0})"
        );
        assert!(
            kernel_mcells >= floor,
            "dispatched kernel regressed: {kernel_mcells:.0} M cells/s is below \
             0.6x the best recorded prior run ({prior:.0} M cells/s)"
        );
    }

    let entry = ObjBuilder::new()
        .field("strings", Json::num(n as f64))
        .field("ladder", Json::num(16))
        .field("reps", Json::num(reps))
        .field("kernel", Json::str(kernel.name()))
        .field("naive_ms_per_pass", Json::num(naive_dt * 1e3))
        .field("legacy_ms_per_pass", Json::num(legacy_dt * 1e3))
        .field("scalar_fused_ms_per_pass", Json::num(scalar_dt * 1e3))
        .field("int_accum_ms_per_pass", Json::num(int_dt * 1e3))
        .field("fused_ms_per_pass", Json::num(fused_dt * 1e3))
        .field("fused_mcells_per_s", Json::num(kernel_mcells))
        .field("kernel_mcells_per_s", Json::num(kernel_mcells))
        .field("speedup_vs_naive", Json::num(speedup_naive))
        .field("speedup_vs_pr1_layout", Json::num(speedup_legacy));
    #[cfg(feature = "simd")]
    let entry = entry
        .field("simd_ms_per_pass", Json::num(simd_dt * 1e3))
        .field("simd_speedup_vs_scalar_fused", Json::num(scalar_dt / simd_dt));
    report.push(("perf_kernel".to_string(), entry.build()));
}

/// Currents path: word-line search over a fully programmed 128K-string
/// block (`search_range`, riding the same tiled cell-major core).
fn perf_block_search() {
    let mut rng = Rng::new(1);
    let n = mcamvss::STRINGS_PER_BLOCK;
    let mut block = McamBlock::new(n, McamParams::default(), VariationModel::IDEAL, 1);
    let mut cells = [0u8; CELLS_PER_STRING];
    for _ in 0..n {
        for c in cells.iter_mut() {
            *c = rng.below(4) as u8;
        }
        block.program_string(&cells);
    }
    let mut wordline = [0u8; CELLS_PER_STRING];
    for c in wordline.iter_mut() {
        *c = rng.below(4) as u8;
    }
    let mut out = Vec::with_capacity(n);
    // warmup
    block.search_range(&wordline, 0, n, &mut out);
    let reps = 10;
    let t0 = Instant::now();
    for _ in 0..reps {
        out.clear();
        block.search_range(&wordline, 0, n, &mut out);
    }
    let dt = t0.elapsed().as_secs_f64();
    let cell_evals = (reps * n * CELLS_PER_STRING) as f64;
    println!("block search: {n} strings x {CELLS_PER_STRING} cells, {reps} reps in {dt:.3}s");
    println!(
        "  {:.1} M strings/s, {:.1} M cell-evals/s\n",
        reps as f64 * n as f64 / dt / 1e6,
        cell_evals / dt / 1e6
    );
    assert_eq!(out.len(), n);
}

/// End-to-end engine search at the paper's Omniglot operating point.
fn perf_engine(report: &mut Vec<(String, Json)>) {
    let mut rng = Rng::new(2);
    let dims = 48;
    let n_vectors = 2000; // 200-way 10-shot
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).map(|i| i / 10).collect();
    let mut modes = ObjBuilder::new();
    for (mode, cl) in [(SearchMode::Avss, 32), (SearchMode::Svss, 32)] {
        let cfg = EngineConfig::new(Encoding::Mtmc, cl, mode, 3.0)
            .with_variation(VariationModel::nand_default());
        let mut engine = SearchEngine::new(cfg, dims, n_vectors).unwrap();
        engine.program_support(&refs, &labels).unwrap();
        let query = SearchRequest::new(&embs[0]);
        engine.search(&query).unwrap(); // warmup
        let reps = 20;
        let t0 = Instant::now();
        for _ in 0..reps {
            engine.search(&query).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "engine {} cl={} ({} vectors, {} strings): {:.2} ms/search, {:.0} searches/s (host)",
            mode.name(),
            cl,
            n_vectors,
            n_vectors * engine.layout().strings_per_vector(),
            dt / reps as f64 * 1e3,
            reps as f64 / dt
        );
        modes = modes.field(
            mode.name(),
            ObjBuilder::new()
                .field("cl", Json::num(cl as f64))
                .field("n_vectors", Json::num(n_vectors as f64))
                .field("ns_per_search", Json::num(dt / reps as f64 * 1e9))
                .field("searches_per_s", Json::num(reps as f64 / dt))
                .build(),
        );
    }
    report.push(("perf_engine".to_string(), modes.build()));
    println!();
}

/// Batched vs scalar search across 1/2/4/8 MCAM shards at the paper's
/// Omniglot operating point (2000 support vectors). Scalar issues one
/// `search` per query; batched drains the same queries through a single
/// `search_batch` call (amortized encoding + one shard fan-out per batch).
fn perf_batch_shards(report: &mut Vec<(String, Json)>) {
    let mut rng = Rng::new(5);
    let dims = 48;
    let n_vectors = 2000; // 200-way 10-shot
    let batch_size = 8;
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).map(|i| i / 10).collect();
    let queries: Vec<SearchRequest> =
        refs.iter().take(batch_size).map(|&q| SearchRequest::new(q)).collect();
    let reps = 6;
    println!("{n_vectors} vectors, MTMC cl=8 AVSS, batch size {batch_size}, {reps} reps");
    let mut baseline_batched = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0)
            .with_variation(VariationModel::nand_default())
            .with_seed(7)
            .with_shards(shards);
        let mut engine = SearchEngine::new(cfg, dims, n_vectors).unwrap();
        engine.program_support(&refs, &labels).unwrap();
        engine.search_batch(&queries).unwrap(); // warmup

        let t0 = Instant::now();
        for _ in 0..reps {
            for q in &queries {
                engine.search(q).unwrap();
            }
        }
        let scalar = (reps * batch_size) as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for _ in 0..reps {
            engine.search_batch(&queries).unwrap();
        }
        let batched = (reps * batch_size) as f64 / t0.elapsed().as_secs_f64();

        if shards == 1 {
            baseline_batched = batched;
        }
        println!(
            "shards={shards}: scalar {scalar:.0}/s, batched {batched:.0}/s \
             (batched/scalar {:.2}x, vs 1-shard batched {:.2}x)",
            batched / scalar,
            batched / baseline_batched.max(1e-9),
        );
        rows.push(
            ObjBuilder::new()
                .field("shards", Json::num(shards as f64))
                .field("scalar_searches_per_s", Json::num(scalar))
                .field("batched_searches_per_s", Json::num(batched))
                .build(),
        );
    }
    report.push((
        "perf_batch_shards".to_string(),
        ObjBuilder::new()
            .field("n_vectors", Json::num(n_vectors as f64))
            .field("batch_size", Json::num(batch_size as f64))
            .field("shards", Json::Arr(rows))
            .build(),
    ));
    println!();
}

/// Cascade acceptance smoke (ISSUE 5): the prune-and-refine schedule must
/// cut sensed strings ≥2× on the 512-slot synth support set at ≤0.5%
/// accuracy drop versus the full AVSS scan — asserted on every run so CI
/// catches a frontier regression — plus host-side throughput of the
/// accepted operating point.
fn perf_cascade(report: &mut Vec<(String, Json)>) {
    use mcamvss::search::cascade::{CascadeConfig, Shortlist};

    let sweep = experiments::fig_cascade::run(0xCA5CADE).unwrap();
    println!("{}", experiments::fig_cascade::render(&sweep));
    let full_acc = sweep.full_scan_accuracy_pct();
    let best = sweep.best_at_reduction(2.0).expect("sweep must include a >=2x point");
    assert!(
        best.reduction >= 2.0,
        "sensed-string reduction {:.2}x below the 2x acceptance bar",
        best.reduction
    );
    let drop = full_acc - best.accuracy_pct;
    assert!(
        drop <= 0.5 + 1e-9,
        "accuracy drop {drop:.2}% > 0.5% at {} (full scan {full_acc:.2}%)",
        best.label
    );
    println!(
        "ACCEPTANCE: {} -> {:.2}x sensed-string reduction, accuracy {:.2}% \
         (full scan {:.2}%, drop {:.2}%)",
        best.label, best.reduction, best.accuracy_pct, full_acc, drop
    );

    // Host throughput at the canonical two-stage point vs the full scan
    // (same 512-slot synth scale; ideal device so runs are deterministic).
    let mut rng = Rng::new(0xCA5);
    let dims = 48;
    let n_vectors = 512;
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).map(|i| i / 8).collect();
    let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0)
        .ideal()
        .with_seed(7);
    let reps = 4;
    let queries = 64;
    let mut measured: Vec<(&str, f64, f64)> = Vec::new();
    for (name, cascade) in [
        ("full_scan", None),
        (
            "cascade_2of8_keep64",
            Some(CascadeConfig::two_stage(2, Shortlist::Count(64))),
        ),
    ] {
        let mut engine = SearchEngine::new(cfg, dims, n_vectors).unwrap();
        engine.program_support(&refs, &labels).unwrap();
        engine.set_cascade(cascade).unwrap();
        engine.search(&SearchRequest::new(&embs[0])).unwrap(); // warmup
        let t0 = Instant::now();
        for _ in 0..reps {
            for q in embs.iter().take(queries) {
                engine.search(&SearchRequest::new(q)).unwrap();
            }
        }
        let per_s = (reps * queries) as f64 / t0.elapsed().as_secs_f64();
        let sensed_per_search =
            engine.energy().sensed_strings as f64 / engine.timing().searches as f64;
        println!(
            "{name}: {per_s:.0} searches/s (host), {sensed_per_search:.0} strings sensed/search"
        );
        measured.push((name, per_s, sensed_per_search));
    }
    println!(
        "host speedup {:.2}x at {:.2}x sensed-string reduction\n",
        measured[1].1 / measured[0].1,
        measured[0].2 / measured[1].2
    );

    report.push((
        "perf_cascade".to_string(),
        ObjBuilder::new()
            .field("full_scan_sensed_per_query", Json::num(sweep.full_scan_sensed))
            .field("full_scan_accuracy_pct", Json::num(full_acc))
            .field("best_label", Json::str(best.label.clone()))
            .field("best_reduction", Json::num(best.reduction))
            .field("best_sensed_per_query", Json::num(best.sensed_per_query))
            .field("best_accuracy_pct", Json::num(best.accuracy_pct))
            .field("best_avg_iterations", Json::num(best.avg_iterations))
            .field("host_full_scan_searches_per_s", Json::num(measured[0].1))
            .field("host_cascade_searches_per_s", Json::num(measured[1].1))
            .field("host_speedup", Json::num(measured[1].1 / measured[0].1))
            .build(),
    ));
}

/// Routing acceptance smoke + the paper-scale sweep: the shard-routing
/// tier must cut sensed shards ≥4× on the clustered 512-slot smoke
/// episode at ≤1% accuracy drop versus the flat scan — asserted on every
/// run — then the 10⁴-slot sweep (16–64 shards × probe budgets) renders
/// the recall/iterations frontier, and a host-side throughput pair
/// (flat vs probe-4 at 32 shards) lands in the tracked report.
fn perf_routing(report: &mut Vec<(String, Json)>) {
    use mcamvss::search::routing::RoutingConfig;

    // Acceptance bar on the CI-sized episode (same assertions as the
    // fig_routing unit test, re-run here so `cargo bench -- perf_routing`
    // is self-checking).
    let smoke = experiments::fig_routing::run_at(
        experiments::fig_routing::Scale::smoke(),
        0xC0A25E,
    )
    .unwrap();
    let flat = smoke.point(16, 0).expect("flat baseline");
    let routed = smoke.point(16, 4).expect("probe-4 point");
    let shard_reduction = flat.shard_senses_per_query / routed.shard_senses_per_query;
    assert!(
        shard_reduction >= 4.0 - 1e-9,
        "sensed-shard reduction {shard_reduction:.2}x below the 4x acceptance bar"
    );
    let drop = flat.accuracy_pct - routed.accuracy_pct;
    assert!(
        drop <= 1.0 + 1e-9,
        "accuracy drop {drop:.2}% > 1% (flat {:.2}%)",
        flat.accuracy_pct
    );
    println!(
        "ACCEPTANCE: {} -> {shard_reduction:.2}x sensed-shard ({:.2}x sensed-string) \
         reduction, accuracy {:.2}% (flat {:.2}%, drop {drop:.2}%)",
        routed.label, routed.reduction, routed.accuracy_pct, flat.accuracy_pct
    );

    // The figure itself, at the 10⁴-slot operating point.
    let t0 = Instant::now();
    let sweep = experiments::fig_routing::run(0xC0A25E).unwrap();
    println!("{}", experiments::fig_routing::render(&sweep));
    println!("[fig_routing wall: {:.1}s]", t0.elapsed().as_secs_f64());

    // Host throughput, flat vs routed, at 10,240 slots x 32 shards.
    let mut rng = Rng::new(0xC0A2);
    let dims = 48;
    let n_vectors = 10_240;
    let shards = 32;
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).map(|i| i / 20).collect();
    let reps = 3;
    let queries = 48;
    let mut measured: Vec<(&str, f64)> = Vec::new();
    for (name, routing) in [("flat", None), ("probe4", Some(RoutingConfig::probe_count(4)))] {
        let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0)
            .ideal()
            .with_seed(7)
            .with_shards(shards);
        let mut engine = SearchEngine::new(cfg, dims, n_vectors).unwrap();
        engine.program_support(&refs, &labels).unwrap();
        engine.set_routing(routing).unwrap();
        engine.search(&SearchRequest::new(&embs[0])).unwrap(); // warmup
        let t0 = Instant::now();
        for _ in 0..reps {
            for q in embs.iter().take(queries) {
                engine.search(&SearchRequest::new(q)).unwrap();
            }
        }
        let per_s = (reps * queries) as f64 / t0.elapsed().as_secs_f64();
        println!("{name} ({n_vectors} slots, {shards} shards): {per_s:.0} searches/s (host)");
        measured.push((name, per_s));
    }
    println!("host speedup {:.2}x from routing\n", measured[1].1 / measured[0].1);

    report.push((
        "perf_routing".to_string(),
        ObjBuilder::new()
            .field("smoke_shard_reduction", Json::num(shard_reduction))
            .field("smoke_string_reduction", Json::num(routed.reduction))
            .field("smoke_flat_accuracy_pct", Json::num(flat.accuracy_pct))
            .field("smoke_routed_accuracy_pct", Json::num(routed.accuracy_pct))
            .field("smoke_flat_agreement_pct", Json::num(routed.flat_agreement_pct))
            .field("sweep_slots", Json::num(sweep.scale_slots as f64))
            .field("host_flat_searches_per_s", Json::num(measured[0].1))
            .field("host_routed_searches_per_s", Json::num(measured[1].1))
            .field("host_speedup", Json::num(measured[1].1 / measured[0].1))
            .build(),
    ));
}

/// Coordinator overhead: served throughput vs bare engine throughput.
fn perf_coordinator() {
    let mut rng = Rng::new(3);
    let dims = 48;
    let n_vectors = 500;
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).collect();
    let ecfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0).ideal();

    // bare engine
    let mut engine = SearchEngine::new(ecfg, dims, n_vectors).unwrap();
    engine.program_support(&refs, &labels).unwrap();
    let reps = 200;
    let t0 = Instant::now();
    for i in 0..reps {
        engine.search(&SearchRequest::new(&embs[i % embs.len()])).unwrap();
    }
    let bare = reps as f64 / t0.elapsed().as_secs_f64();

    for workers in [1, 2, 4] {
        let server = Server::start(
            CoordinatorConfig { workers, queue_capacity: 512, ..Default::default() },
            ecfg,
            dims,
            &refs,
            &labels,
            mcamvss::coordinator::worker::identity_embed(),
        )
        .unwrap();
        let t0 = Instant::now();
        for i in 0..reps {
            server.submit(Payload::Embedding(embs[i % embs.len()].clone()));
        }
        let responses = server.shutdown();
        let served = responses.len() as f64 / t0.elapsed().as_secs_f64();
        println!(
            "coordinator {workers} worker(s): {served:.0} req/s (bare engine {bare:.0}/s, {:.2}x)",
            served / bare
        );
    }
    println!();
}

/// Top-k selection cost on the serving path: top-1 vs top-5 vs the
/// dense `full_scores` dump at 1/4/8 shards (ISSUE 3 acceptance point).
/// The bounded heap keeps ranked retrieval within noise of winner-only
/// search; materializing dense scores pays the O(N) copy per query.
fn perf_topk(report: &mut Vec<(String, Json)>) {
    let mut rng = Rng::new(9);
    let dims = 48;
    let n_vectors = 2000; // 200-way 10-shot
    let batch_size = 8;
    let embs: Vec<Vec<f32>> = (0..n_vectors)
        .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 3.0) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = embs.iter().map(|e| e.as_slice()).collect();
    let labels: Vec<u32> = (0..n_vectors as u32).map(|i| i / 10).collect();
    let reps = 6;
    println!("{n_vectors} vectors, MTMC cl=8 AVSS, batch size {batch_size}, {reps} reps");
    let mut rows: Vec<Json> = Vec::new();
    for shards in [1usize, 4, 8] {
        let cfg = EngineConfig::new(Encoding::Mtmc, 8, SearchMode::Avss, 3.0)
            .with_variation(VariationModel::nand_default())
            .with_seed(7)
            .with_shards(shards);
        let mut engine = SearchEngine::new(cfg, dims, n_vectors).unwrap();
        engine.program_support(&refs, &labels).unwrap();
        let mut measured = Vec::new();
        for (name, top_k, dense) in
            [("top1", 1usize, false), ("top5", 5, false), ("full_scores", 5, true)]
        {
            let requests: Vec<SearchRequest> = refs
                .iter()
                .take(batch_size)
                .map(|&q| {
                    let request = SearchRequest::new(q).with_top_k(top_k);
                    if dense {
                        request.with_full_scores()
                    } else {
                        request
                    }
                })
                .collect();
            engine.search_batch(&requests).unwrap(); // warmup
            let t0 = Instant::now();
            for _ in 0..reps {
                engine.search_batch(&requests).unwrap();
            }
            let per_s = (reps * batch_size) as f64 / t0.elapsed().as_secs_f64();
            measured.push((name, per_s));
        }
        println!(
            "shards={shards}: top1 {:.0}/s, top5 {:.0}/s, full_scores {:.0}/s",
            measured[0].1, measured[1].1, measured[2].1
        );
        let mut row = ObjBuilder::new().field("shards", Json::num(shards as f64));
        for (name, per_s) in measured {
            row = row.field(&format!("{name}_searches_per_s"), Json::num(per_s));
        }
        rows.push(row.build());
    }
    report.push((
        "perf_topk".to_string(),
        ObjBuilder::new()
            .field("n_vectors", Json::num(n_vectors as f64))
            .field("batch_size", Json::num(batch_size as f64))
            .field("shards", Json::Arr(rows))
            .build(),
    ));
    println!();
}

/// SA ladder voting microbenchmark.
fn perf_sense() {
    let ladder = SenseLadder::new(&McamParams::default(), 16);
    let mut rng = Rng::new(4);
    let currents: Vec<f64> = (0..1_000_000).map(|_| rng.range_f64(0.001, 1.0)).collect();
    let t0 = Instant::now();
    let mut acc = 0u64;
    for &c in &currents {
        acc += ladder.votes(c) as u64;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "sense ladder: {:.1} M votes/s (checksum {acc})\n",
        currents.len() as f64 / dt / 1e6
    );
}
